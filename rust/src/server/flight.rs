//! Singleflight request coalescing (DESIGN.md §11).
//!
//! Identical concurrent `/simulate` and `/sweep` requests should cost
//! one simulation, not N. The flight table keys in-progress work by the
//! same content fingerprints the caches use ([`crate::compiler::program_key`]
//! / `system_key`, mixed with the request mode), so "identical" is
//! *semantic* identity: the first arrival becomes the **leader** and
//! runs the job; later arrivals become **followers** and wait on a
//! channel for the leader's finished `(status, body)` — every coalesced
//! response is byte-identical by construction because it *is* the same
//! bytes behind a shared `Arc`.
//!
//! Crash safety: the leader holds a [`FlightGuard`]. Publishing the
//! outcome consumes the guard; if the leader's handler unwinds instead,
//! the guard's `Drop` publishes a 500 so followers can never hang on a
//! dead leader.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};

/// A finished request as shared between leader and followers.
pub struct Outcome {
    pub status: u16,
    pub body: String,
    /// `X-Snax-Cache` value when the simulate path produced one.
    pub cache: Option<&'static str>,
}

/// Result of joining a flight: run the job or wait for whoever is.
pub enum Join<'a> {
    Leader(FlightGuard<'a>),
    Follower(Receiver<Arc<Outcome>>),
}

/// In-flight table: key → followers waiting on the leader's outcome.
#[derive(Default)]
pub struct Flight {
    inner: Mutex<HashMap<u64, Vec<SyncSender<Arc<Outcome>>>>>,
    coalesced: AtomicU64,
}

impl Flight {
    /// Join the flight for `key`: the first caller leads, the rest
    /// follow. The leader *must* let its guard publish (explicitly or
    /// by drop) or followers would wait out their deadlines.
    pub fn join(&self, key: u64) -> Join<'_> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(waiters) = inner.get_mut(&key) {
            let (tx, rx) = std::sync::mpsc::sync_channel(1);
            waiters.push(tx);
            self.coalesced.fetch_add(1, Ordering::Relaxed);
            Join::Follower(rx)
        } else {
            inner.insert(key, Vec::new());
            Join::Leader(FlightGuard { flight: self, key, published: false })
        }
    }

    /// Requests served as coalesced followers (`snax_coalesced_total`).
    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    fn resolve(&self, key: u64, outcome: Arc<Outcome>) {
        let waiters = self.inner.lock().unwrap().remove(&key);
        for tx in waiters.into_iter().flatten() {
            // A follower that gave up (deadline) dropped its receiver;
            // that is its problem, not ours.
            let _ = tx.send(outcome.clone());
        }
    }
}

/// Leadership of one flight key. Publish the outcome with
/// [`FlightGuard::publish`]; dropping unpublished (leader unwound)
/// publishes a 500 instead.
pub struct FlightGuard<'a> {
    flight: &'a Flight,
    key: u64,
    published: bool,
}

impl FlightGuard<'_> {
    pub fn publish(mut self, outcome: Arc<Outcome>) {
        self.published = true;
        self.flight.resolve(self.key, outcome);
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        if !self.published {
            self.flight.resolve(
                self.key,
                Arc::new(Outcome {
                    status: 500,
                    body: "{\"error\":\"coalesced leader failed before producing a response\"}"
                        .to_string(),
                    cache: None,
                }),
            );
        }
    }
}

/// FNV-1a over little-endian words — the flight key mixer. Callers
/// fold the cache fingerprint with request facets (mode, profile,
/// deadline) that change the response bytes.
pub fn mix_key(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn leader_then_followers_share_one_outcome() {
        let flight = Flight::default();
        let Join::Leader(guard) = flight.join(7) else {
            panic!("first join must lead")
        };
        let rx_a = match flight.join(7) {
            Join::Follower(rx) => rx,
            Join::Leader(_) => panic!("second join must follow"),
        };
        let rx_b = match flight.join(7) {
            Join::Follower(rx) => rx,
            Join::Leader(_) => panic!("third join must follow"),
        };
        assert_eq!(flight.coalesced(), 2);
        guard.publish(Arc::new(Outcome {
            status: 200,
            body: "report".into(),
            cache: Some("miss"),
        }));
        let a = rx_a.recv_timeout(Duration::from_secs(5)).unwrap();
        let b = rx_b.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "followers share the same bytes");
        assert_eq!((a.status, a.body.as_str(), a.cache), (200, "report", Some("miss")));
        // The key is free again.
        assert!(matches!(flight.join(7), Join::Leader(_)));
    }

    #[test]
    fn dropped_guard_publishes_a_500_so_followers_never_hang() {
        let flight = Flight::default();
        let Join::Leader(guard) = flight.join(1) else { panic!() };
        let Join::Follower(rx) = flight.join(1) else { panic!() };
        drop(guard); // leader "panicked"
        let out = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(out.status, 500);
        assert!(out.body.contains("leader failed"));
    }

    #[test]
    fn distinct_keys_do_not_coalesce() {
        let flight = Flight::default();
        let Join::Leader(a) = flight.join(1) else { panic!() };
        assert!(matches!(flight.join(2), Join::Leader(_)));
        assert_eq!(flight.coalesced(), 0);
        drop(a);
    }

    #[test]
    fn mix_key_separates_facets() {
        let base = 0x1234_5678_9abc_def0_u64;
        let a = mix_key(&[base, 0, 0]);
        let b = mix_key(&[base, 1, 0]);
        let c = mix_key(&[base, 0, 250]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, mix_key(&[base, 0, 0]));
    }
}
