//! Crash-safe job journal for `snax serve` (DESIGN.md §12).
//!
//! The journal is an append-only record log that makes the detached-job
//! table durable across process death. Every job transition appends one
//! length-prefixed, checksummed record:
//!
//! ```text
//! [u32 LE payload len][u64 LE FNV-1a(payload)][payload bytes]
//! ```
//!
//! Record kinds (first payload byte):
//!
//! * `Submitted { id, body }` — the job was accepted; `body` is the
//!   original request JSON, enough to re-run the job from scratch.
//! * `Started { id, seq }` — a worker picked the job up (`seq` is its
//!   fault-roll sequence number, recorded for post-mortem debugging).
//! * `Checkpointed { id, path }` — the engine wrote a durable
//!   barrier-boundary checkpoint for this job.
//! * `Terminal { id, state, body }` — the job reached a terminal state
//!   (`done`/`failed`/`cancelled`/`interrupted`) with its rendered
//!   result or error.
//!
//! Fsync policy: terminal records are `fdatasync`'d so a completed
//! job's outcome survives power loss; non-terminal records are only
//! `write(2)`-durable (they survive *process* death — the page cache
//! outlives the process — which is the failure mode the `crash:p`
//! fault and the crash-recovery harness exercise).
//!
//! On startup [`Journal::open`] replays the log: records are decoded
//! until the first bad checksum or truncated frame, the file is
//! truncated back to the last good offset (a torn tail is dropped, not
//! a panic), and the decoded records are handed to the server's
//! recovery pass ([`replay`] folds them into per-job summaries).

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::compiler::fingerprint::Fnv1a;
use crate::sim::checkpoint::{Dec, Enc};

/// Record kind tags (first payload byte).
const TAG_SUBMITTED: u8 = 1;
const TAG_STARTED: u8 = 2;
const TAG_CHECKPOINTED: u8 = 3;
const TAG_TERMINAL: u8 = 4;

/// Bound on one record's payload (a rendered report body plus framing;
/// a corrupt length prefix must not drive a multi-gigabyte allocation).
const MAX_RECORD_BYTES: u32 = 64 * 1024 * 1024;

/// Terminal state of a journaled job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TerminalState {
    Done,
    Failed,
    Cancelled,
    /// The process died (or drained on SIGTERM) while the job was in
    /// flight; the job is resumable from its latest checkpoint.
    Interrupted,
}

impl TerminalState {
    pub fn as_str(self) -> &'static str {
        match self {
            TerminalState::Done => "done",
            TerminalState::Failed => "failed",
            TerminalState::Cancelled => "cancelled",
            TerminalState::Interrupted => "interrupted",
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            TerminalState::Done => 0,
            TerminalState::Failed => 1,
            TerminalState::Cancelled => 2,
            TerminalState::Interrupted => 3,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => TerminalState::Done,
            1 => TerminalState::Failed,
            2 => TerminalState::Cancelled,
            3 => TerminalState::Interrupted,
            other => bail!("unknown terminal state tag {other}"),
        })
    }
}

/// One journal record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Record {
    Submitted { id: u64, body: String },
    Started { id: u64, seq: u64 },
    Checkpointed { id: u64, path: String },
    Terminal { id: u64, state: TerminalState, body: String },
}

impl Record {
    fn encode(&self) -> Vec<u8> {
        let mut e = Enc { buf: Vec::with_capacity(64) };
        match self {
            Record::Submitted { id, body } => {
                e.u8(TAG_SUBMITTED);
                e.u64(*id);
                e.string(body);
            }
            Record::Started { id, seq } => {
                e.u8(TAG_STARTED);
                e.u64(*id);
                e.u64(*seq);
            }
            Record::Checkpointed { id, path } => {
                e.u8(TAG_CHECKPOINTED);
                e.u64(*id);
                e.string(path);
            }
            Record::Terminal { id, state, body } => {
                e.u8(TAG_TERMINAL);
                e.u64(*id);
                e.u8(state.to_u8());
                e.string(body);
            }
        }
        e.buf
    }

    fn decode(payload: &[u8]) -> Result<Record> {
        let mut d = Dec::new(payload);
        let rec = match d.u8()? {
            TAG_SUBMITTED => Record::Submitted { id: d.u64()?, body: d.string()? },
            TAG_STARTED => Record::Started { id: d.u64()?, seq: d.u64()? },
            TAG_CHECKPOINTED => Record::Checkpointed { id: d.u64()?, path: d.string()? },
            TAG_TERMINAL => Record::Terminal {
                id: d.u64()?,
                state: TerminalState::from_u8(d.u8()?)?,
                body: d.string()?,
            },
            other => bail!("unknown journal record tag {other}"),
        };
        d.finish()?;
        Ok(rec)
    }
}

fn checksum(payload: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write_bytes(payload);
    h.finish()
}

/// Frame one record: `[u32 LE len][u64 LE FNV-1a][payload]`. The same
/// discipline frames fleet peer-protocol bodies (`server/peer.rs`).
fn frame(rec: &Record) -> Vec<u8> {
    let payload = rec.encode();
    let mut framed = Vec::with_capacity(12 + payload.len());
    framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    framed.extend_from_slice(&checksum(&payload).to_le_bytes());
    framed.extend_from_slice(&payload);
    framed
}

/// Decode framed records from `bytes`. Returns the records up to the
/// first corrupt or truncated frame and the byte offset of the last
/// good frame boundary — a torn tail is reported, never a panic.
pub(crate) fn decode_all(bytes: &[u8]) -> (Vec<Record>, usize) {
    let mut records = Vec::new();
    let mut pos = 0usize;
    loop {
        let Some(header) = bytes.get(pos..pos + 12) else { break };
        let len = u32::from_le_bytes(header[0..4].try_into().unwrap());
        if len > MAX_RECORD_BYTES {
            break;
        }
        let sum = u64::from_le_bytes(header[4..12].try_into().unwrap());
        let Some(payload) = bytes.get(pos + 12..pos + 12 + len as usize) else { break };
        if checksum(payload) != sum {
            break;
        }
        let Ok(rec) = Record::decode(payload) else { break };
        records.push(rec);
        pos += 12 + len as usize;
    }
    (records, pos)
}

/// The append-only journal file. Writes are serialized by an internal
/// lock; the running byte length is exported as a metrics gauge.
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
    bytes: AtomicU64,
}

impl Journal {
    /// Open (or create) the journal at `path`, replaying any existing
    /// records. A corrupt or truncated tail — the signature of a crash
    /// mid-append — is truncated away so subsequent appends extend a
    /// clean log.
    pub fn open(path: &Path) -> Result<(Journal, Vec<Record>)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)
            .with_context(|| format!("reading journal {}", path.display()))?;
        let (records, good) = decode_all(&bytes);
        if good < bytes.len() {
            file.set_len(good as u64)
                .with_context(|| format!("truncating torn tail of {}", path.display()))?;
        }
        file.seek(SeekFrom::Start(good as u64)).context("seeking journal end")?;
        Ok((
            Journal {
                path: path.to_path_buf(),
                file: Mutex::new(file),
                bytes: AtomicU64::new(good as u64),
            },
            records,
        ))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Current journal length in bytes (the `snax_journal_bytes` gauge).
    pub fn len_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn write_record(&self, rec: &Record, sync: bool) -> Result<()> {
        let framed = frame(rec);
        let mut file = self.file.lock().unwrap();
        file.write_all(&framed)
            .with_context(|| format!("appending to journal {}", self.path.display()))?;
        if sync {
            file.sync_data()
                .with_context(|| format!("syncing journal {}", self.path.display()))?;
        }
        self.bytes.fetch_add(framed.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Append a non-terminal record (durable against process death —
    /// the write reaches the page cache before the call returns).
    pub fn append(&self, rec: &Record) -> Result<()> {
        self.write_record(rec, false)
    }

    /// Append a terminal record and `fdatasync` it, so a job's outcome
    /// also survives power loss (the fsync policy boundary).
    pub fn append_sync(&self, rec: &Record) -> Result<()> {
        self.write_record(rec, true)
    }

    /// Compact the journal: fold the current record stream into per-job
    /// summaries ([`replay`]), drop every job `keep` rejects (evicted
    /// jobs whose history only wastes replay time), and rewrite the
    /// survivors' *essential* records — one `Submitted`, the last
    /// `Started`, every `Checkpointed`, the `Terminal` if any — to a
    /// fresh file that is fsync'd and atomically renamed over the old
    /// one. By construction replaying the compacted log yields exactly
    /// the same [`JobRecovery`] map restricted to the kept ids (the
    /// summary *is* the source of the rewritten records).
    ///
    /// Runs under the file lock, so concurrent appends serialize either
    /// entirely before (and are folded in) or entirely after (and
    /// extend the fresh log). Returns the compacted length in bytes.
    pub fn compact(&self, keep: impl Fn(u64) -> bool) -> Result<u64> {
        let mut file = self.file.lock().unwrap();
        let mut bytes = Vec::new();
        file.seek(SeekFrom::Start(0)).context("seeking journal start")?;
        file.read_to_end(&mut bytes)
            .with_context(|| format!("re-reading journal {}", self.path.display()))?;
        let (records, _) = decode_all(&bytes);
        let jobs = replay(&records);

        let tmp = self.path.with_extension("compacting");
        let mut out = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut len = 0u64;
        for (id, job) in &jobs {
            if !keep(*id) {
                continue;
            }
            let mut essential = Vec::new();
            if let Some(body) = &job.body {
                essential.push(Record::Submitted { id: *id, body: body.clone() });
            }
            if let Some(seq) = job.seq {
                essential.push(Record::Started { id: *id, seq });
            }
            for path in &job.checkpoints {
                essential.push(Record::Checkpointed { id: *id, path: path.clone() });
            }
            if let Some((state, body)) = &job.terminal {
                essential.push(Record::Terminal {
                    id: *id,
                    state: *state,
                    body: body.clone(),
                });
            }
            for rec in &essential {
                let framed = frame(rec);
                out.write_all(&framed)
                    .with_context(|| format!("writing {}", tmp.display()))?;
                len += framed.len() as u64;
            }
        }
        out.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path).with_context(|| {
            format!("renaming {} over {}", tmp.display(), self.path.display())
        })?;
        // Swap the handle to the fresh file so subsequent appends
        // extend the compacted log, not the unlinked old inode.
        let mut fresh = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&self.path)
            .with_context(|| format!("reopening compacted {}", self.path.display()))?;
        fresh.seek(SeekFrom::End(0)).context("seeking compacted journal end")?;
        *file = fresh;
        self.bytes.store(len, Ordering::Relaxed);
        Ok(len)
    }
}

/// Per-job summary folded from a replayed record stream.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct JobRecovery {
    /// Original request JSON (from `Submitted`).
    pub body: Option<String>,
    /// Fault-roll sequence of the last `Started` (post-mortem info).
    pub seq: Option<u64>,
    /// Checkpoint files written, in order; the last is the newest.
    pub checkpoints: Vec<String>,
    /// Terminal outcome, if the job got one before the process died.
    pub terminal: Option<(TerminalState, String)>,
}

/// Fold a replayed record stream into per-job summaries. A job whose
/// summary has `body` but no `terminal` was in flight when the process
/// died — the recovery pass marks it interrupted and auto-resumes it
/// from `checkpoints.last()`.
pub fn replay(records: &[Record]) -> BTreeMap<u64, JobRecovery> {
    let mut jobs: BTreeMap<u64, JobRecovery> = BTreeMap::new();
    for rec in records {
        match rec {
            Record::Submitted { id, body } => {
                jobs.entry(*id).or_default().body = Some(body.clone());
            }
            Record::Started { id, seq } => {
                let j = jobs.entry(*id).or_default();
                j.seq = Some(*seq);
                // A restart of a previously-terminal job (POST resume)
                // reopens it: the old outcome no longer stands.
                j.terminal = None;
            }
            Record::Checkpointed { id, path } => {
                jobs.entry(*id).or_default().checkpoints.push(path.clone());
            }
            Record::Terminal { id, state, body } => {
                jobs.entry(*id).or_default().terminal = Some((*state, body.clone()));
            }
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("snax-journal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("jobs.journal")
    }

    fn sample_records() -> Vec<Record> {
        vec![
            Record::Submitted { id: 1, body: r#"{"net":"fig6a"}"#.into() },
            Record::Started { id: 1, seq: 0 },
            Record::Checkpointed { id: 1, path: "ckpts/job1/a.ckpt".into() },
            Record::Terminal {
                id: 1,
                state: TerminalState::Done,
                body: r#"{"total_cycles":42}"#.into(),
            },
            Record::Submitted { id: 2, body: r#"{"net":"dae"}"#.into() },
            Record::Started { id: 2, seq: 1 },
        ]
    }

    #[test]
    fn roundtrips_records_across_reopen() {
        let path = tmp("roundtrip");
        let (journal, replayed) = Journal::open(&path).unwrap();
        assert!(replayed.is_empty());
        for rec in sample_records() {
            journal.append(&rec).unwrap();
        }
        journal
            .append_sync(&Record::Terminal {
                id: 2,
                state: TerminalState::Interrupted,
                body: "drained".into(),
            })
            .unwrap();
        let written = journal.len_bytes();
        drop(journal);
        let (journal2, replayed2) = Journal::open(&path).unwrap();
        assert_eq!(replayed2.len(), 7);
        assert_eq!(replayed2[..6], sample_records());
        assert_eq!(journal2.len_bytes(), written);
    }

    #[test]
    fn corrupted_tail_is_dropped_not_a_panic() {
        let path = tmp("corrupt");
        let (journal, _) = Journal::open(&path).unwrap();
        for rec in sample_records() {
            journal.append(&rec).unwrap();
        }
        let good_len = journal.len_bytes();
        drop(journal);
        // Flip a byte inside the last record's payload: its checksum no
        // longer matches, so replay must drop it (and only it).
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 3] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let (journal2, replayed) = Journal::open(&path).unwrap();
        assert_eq!(replayed.len(), sample_records().len() - 1);
        assert!(journal2.len_bytes() < good_len, "torn tail must be truncated");
        // The log is clean again: appends after recovery replay fine.
        journal2.append_sync(&Record::Started { id: 2, seq: 9 }).unwrap();
        drop(journal2);
        let (_, replayed3) = Journal::open(&path).unwrap();
        assert_eq!(replayed3.last(), Some(&Record::Started { id: 2, seq: 9 }));
    }

    #[test]
    fn truncated_tail_is_dropped_not_a_panic() {
        let path = tmp("truncate");
        let (journal, _) = Journal::open(&path).unwrap();
        for rec in sample_records() {
            journal.append(&rec).unwrap();
        }
        drop(journal);
        // Cut the file mid-frame, as a crash mid-append would.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let (_, replayed) = Journal::open(&path).unwrap();
        assert_eq!(replayed.len(), sample_records().len() - 1);
        // Garbage-only file: zero records, no panic.
        std::fs::write(&path, b"not a journal at all").unwrap();
        let (_, replayed2) = Journal::open(&path).unwrap();
        assert!(replayed2.is_empty());
    }

    #[test]
    fn replay_folds_records_into_job_summaries() {
        let mut records = sample_records();
        records.push(Record::Checkpointed { id: 2, path: "ckpts/job2/b.ckpt".into() });
        let jobs = replay(&records);
        assert_eq!(jobs.len(), 2);
        let j1 = &jobs[&1];
        assert_eq!(j1.body.as_deref(), Some(r#"{"net":"fig6a"}"#));
        assert_eq!(j1.terminal, Some((TerminalState::Done, r#"{"total_cycles":42}"#.into())));
        let j2 = &jobs[&2];
        assert_eq!(j2.seq, Some(1));
        assert!(j2.terminal.is_none(), "job 2 was in flight — orphaned");
        assert_eq!(j2.checkpoints, vec!["ckpts/job2/b.ckpt".to_string()]);
    }

    #[test]
    fn compaction_replays_to_the_same_recovery_map() {
        let path = tmp("compact");
        let (journal, _) = Journal::open(&path).unwrap();
        // Redundant history: duplicate submissions, a resume cycle, and
        // checkpoints — compaction must fold it without changing what
        // replay sees.
        let noisy = vec![
            Record::Submitted { id: 1, body: r#"{"net":"fig6a"}"#.into() },
            Record::Started { id: 1, seq: 0 },
            Record::Checkpointed { id: 1, path: "ckpts/job1/a.ckpt".into() },
            Record::Checkpointed { id: 1, path: "ckpts/job1/b.ckpt".into() },
            Record::Terminal {
                id: 1,
                state: TerminalState::Interrupted,
                body: "killed".into(),
            },
            Record::Started { id: 1, seq: 5 }, // resume reopens the job
            Record::Terminal {
                id: 1,
                state: TerminalState::Done,
                body: r#"{"total_cycles":42}"#.into(),
            },
            Record::Submitted { id: 2, body: r#"{"net":"dae"}"#.into() },
            Record::Started { id: 2, seq: 1 },
            Record::Submitted { id: 3, body: "{}".into() },
            Record::Terminal { id: 3, state: TerminalState::Failed, body: "boom".into() },
        ];
        for rec in &noisy {
            journal.append(rec).unwrap();
        }
        let before_bytes = journal.len_bytes();
        let before_map = replay(&noisy);

        let after_bytes = journal.compact(|_| true).unwrap();
        assert!(after_bytes < before_bytes, "folding history must shrink the log");
        assert_eq!(journal.len_bytes(), after_bytes);
        drop(journal);
        let (journal2, compacted) = Journal::open(&path).unwrap();
        assert_eq!(
            replay(&compacted),
            before_map,
            "compacted journal must replay to the same JobRecovery map"
        );

        // Dropping evicted jobs removes exactly their entries.
        journal2.compact(|id| id != 2).unwrap();
        drop(journal2);
        let (journal3, pruned) = Journal::open(&path).unwrap();
        let pruned_map = replay(&pruned);
        let mut expect = before_map.clone();
        expect.remove(&2);
        assert_eq!(pruned_map, expect);

        // Appends after compaction extend the fresh file, not the
        // unlinked old inode.
        journal3.append_sync(&Record::Started { id: 3, seq: 9 }).unwrap();
        drop(journal3);
        let (_, reread) = Journal::open(&path).unwrap();
        assert_eq!(reread.last(), Some(&Record::Started { id: 3, seq: 9 }));
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn started_after_terminal_reopens_a_job() {
        // POST /jobs/:id/resume writes Started for a formerly-terminal
        // job; replay must treat it as live again.
        let records = vec![
            Record::Submitted { id: 7, body: "{}".into() },
            Record::Started { id: 7, seq: 0 },
            Record::Terminal {
                id: 7,
                state: TerminalState::Interrupted,
                body: "killed".into(),
            },
            Record::Started { id: 7, seq: 3 },
        ];
        let jobs = replay(&records);
        assert!(jobs[&7].terminal.is_none());
        assert_eq!(jobs[&7].seq, Some(3));
    }
}
