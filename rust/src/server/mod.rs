//! `snax serve` — the concurrent compile-and-simulate service layer.
//!
//! The single-shot CLI couples one workload to one process; this module
//! turns the same compiler + simulator into a long-running service so
//! many clients can submit workloads concurrently (DESIGN.md §6):
//!
//! * [`http`] — dependency-light HTTP/1.1 framing over
//!   `std::net::TcpListener` (no hyper/axum in this environment);
//! * [`api`] — the endpoints: `POST /compile`, `POST /simulate`,
//!   `POST /sweep` (parallel batch fan-out with deterministic result
//!   ordering), `GET /jobs/:id`, `GET /healthz`, `GET /metrics`;
//! * [`cache`] — sharded content-addressed compiled-program cache keyed
//!   by [`crate::compiler::program_key`], so repeat simulations skip
//!   the compiler entirely;
//! * [`pool`] — bounded worker pool executing compile+simulate jobs
//!   across cores with 503 backpressure and graceful drain;
//! * [`admission`] — per-client token-bucket quotas and the three-state
//!   circuit breaker shedding with `Retry-After` (DESIGN.md §11);
//! * [`flight`] — singleflight coalescing of identical concurrent
//!   requests onto one simulation;
//! * [`fault`] — deterministic fault injection for the chaos harness;
//! * [`journal`] — crash-safe append-only job journal replayed at
//!   startup so detached jobs survive process death (DESIGN.md §12);
//! * [`ring`] / [`peer`] — fleet mode (`--peers`): a consistent-hash
//!   ring shards the content-addressed caches across peer servers, the
//!   peer client wraps the internal cache protocol in timeouts,
//!   retries, and per-peer breakers, and every peer failure degrades
//!   gracefully to node-local behavior (DESIGN.md §13).
//!
//! Threading model: one cheap thread per connection parses requests and
//! writes responses; every heavy job runs on the fixed-size worker pool
//! (one simulation per worker). SIGINT/SIGTERM (or
//! [`Server::shutdown`]) flip a shutdown flag: the acceptor stops,
//! keep-alive connections end after their in-flight response, and the
//! pool drains queued jobs before the process exits.

pub mod admission;
pub mod api;
pub mod cache;
pub mod fault;
pub mod flight;
pub mod http;
pub mod journal;
pub mod peer;
pub mod pool;
pub mod ring;

use std::io::{BufReader, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::ServerConfig;

use api::AppState;

pub use api::{ledger_json, render_report, render_sweep_body, render_system_report};

/// How long an idle keep-alive connection may sit between requests.
const READ_TIMEOUT: Duration = Duration::from_secs(30);
/// Wall deadline for reading one complete request once its first byte
/// has arrived. The per-read idle timeout alone does not bound a
/// slowloris client that dribbles one byte per interval; this does.
const REQUEST_READ_DEADLINE: Duration = Duration::from_secs(30);
/// Socket write timeout: a client that stops draining its receive
/// window must not pin a connection thread forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);
/// Acceptor poll interval (the listener is non-blocking so shutdown is
/// observed promptly).
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Per-connection I/O limits. `Default` is production sizing; the
/// timeout tests shrink them to drive the cut-off paths quickly.
#[derive(Clone, Copy)]
struct ConnLimits {
    /// Idle gap allowed while waiting for a request to start.
    idle: Duration,
    /// Wall deadline per request read (the slowloris bound).
    request: Duration,
    /// Socket write timeout.
    write: Duration,
}

impl Default for ConnLimits {
    fn default() -> Self {
        ConnLimits {
            idle: READ_TIMEOUT,
            request: REQUEST_READ_DEADLINE,
            write: WRITE_TIMEOUT,
        }
    }
}

/// Read half of a connection enforcing [`ConnLimits`]: while no request
/// is in progress each read waits up to `idle`; the first byte of a
/// request arms a wall deadline, after which every read is capped at
/// the time remaining. A dribbling client therefore cannot hold the
/// connection past `request` no matter how often it sends one byte.
struct DeadlineStream {
    stream: TcpStream,
    limits: ConnLimits,
    /// Wall deadline of the in-progress request, armed on first byte.
    deadline: Option<Instant>,
}

impl DeadlineStream {
    /// Called between requests: the next byte starts a fresh deadline.
    fn begin_request(&mut self) {
        self.deadline = None;
    }
}

impl Read for DeadlineStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let timeout = match self.deadline {
            None => self.limits.idle,
            Some(deadline) => {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "request read exceeded the wall deadline",
                    ));
                }
                remaining.min(self.limits.idle)
            }
        };
        self.stream.set_read_timeout(Some(timeout))?;
        let n = self.stream.read(buf)?;
        if n > 0 && self.deadline.is_none() {
            self.deadline = Some(Instant::now() + self.limits.request);
        }
        Ok(n)
    }
}

/// A running service instance. Bind with [`Server::start`], stop with
/// [`Server::shutdown`] (tests and the load generator run it
/// in-process; the CLI wraps it in [`run_blocking`]).
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    state: Arc<AppState>,
}

impl Server {
    /// Bind 127.0.0.1:`cfg.port` (0 = ephemeral) and start serving.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        cfg.validate()?;
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
        listener.set_nonblocking(true).context("setting listener non-blocking")?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let state = Arc::new(AppState::new(&cfg)?);
        // Replay the job journal before accepting traffic: terminal
        // jobs become pollable again and interrupted ones re-enter the
        // pool from their latest checkpoint (DESIGN.md §12).
        api::recover_jobs(&state);
        let accept_state = state.clone();
        let accept_shutdown = shutdown.clone();
        let accept_thread = std::thread::Builder::new()
            .name("snax-accept".into())
            .spawn(move || accept_loop(listener, accept_state, accept_shutdown))
            .context("spawning acceptor thread")?;
        Ok(Server { addr, shutdown, accept_thread: Some(accept_thread), state })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn port(&self) -> u16 {
        self.addr.port()
    }

    /// Shared application state (metrics, cache) for in-process
    /// inspection by tests and the load generator.
    pub fn state(&self) -> &Arc<AppState> {
        &self.state
    }

    /// Graceful shutdown: stop accepting, end keep-alive connections
    /// after their in-flight response, drain queued jobs, join workers.
    /// (Dropping a `Server` does the same; this name just makes call
    /// sites read as intent.)
    pub fn shutdown(self) {
        drop(self);
    }

    fn teardown(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(handle) = self.accept_thread.take() {
            let _ = handle.join();
        }
        self.state.begin_drain();
        self.state.pool.shutdown();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.teardown();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<AppState>, shutdown: Arc<AtomicBool>) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let conn_state = state.clone();
                let spawned = std::thread::Builder::new()
                    .name("snax-conn".into())
                    .spawn(move || handle_connection(stream, conn_state));
                if spawned.is_err() {
                    // Out of threads: back off instead of spinning.
                    std::thread::sleep(ACCEPT_POLL);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn handle_connection(stream: TcpStream, state: Arc<AppState>) {
    handle_connection_with(stream, state, ConnLimits::default());
}

fn handle_connection_with(stream: TcpStream, state: Arc<AppState>, limits: ConnLimits) {
    let _ = stream.set_write_timeout(Some(limits.write));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader =
        BufReader::new(DeadlineStream { stream: read_half, limits, deadline: None });
    let mut writer = stream;
    loop {
        reader.get_mut().begin_request();
        match http::read_request(&mut reader) {
            Ok(Some(request)) => {
                let keep_alive = request.keep_alive();
                let response = api::route(&state, &request);
                if response.write_to(&mut writer).is_err() {
                    return;
                }
                if !keep_alive || state.shutting_down() {
                    return;
                }
            }
            // Clean close between requests.
            Ok(None) => return,
            Err(http::HttpError::Malformed(msg)) => {
                let _ = http::Response::text(400, &format!("bad request: {msg}\n"))
                    .write_to(&mut writer);
                return;
            }
            // Timeout / reset: nothing sensible to send.
            Err(http::HttpError::Io(_)) => return,
        }
    }
}

// ---------------------------------------------------------------------------
// CLI entry point: blocking serve with signal-driven shutdown
// ---------------------------------------------------------------------------

static GOT_SIGNAL: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    // No libc crate in this environment; bind the libc `signal` symbol
    // directly. The handler only flips an atomic flag, which is
    // async-signal-safe; the run loop below does the actual work.
    extern "C" fn on_signal(_signum: i32) {
        GOT_SIGNAL.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    let handler = on_signal as extern "C" fn(i32);
    unsafe {
        signal(SIGINT, handler as usize);
        signal(SIGTERM, handler as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Run the service until SIGINT/SIGTERM, then shut down gracefully.
/// This is `snax serve`.
pub fn run_blocking(cfg: ServerConfig) -> Result<()> {
    install_signal_handlers();
    let server = Server::start(cfg)?;
    let cfg = &server.state().server_cfg;
    println!(
        "snax serve listening on http://{} ({} workers, cache {} entries, queue depth {}, \
         breaker {}, default deadline {})",
        server.addr(),
        cfg.workers,
        cfg.cache_capacity,
        cfg.queue_depth,
        if cfg.breaker { "on" } else { "off" },
        if cfg.default_deadline_ms == 0 {
            "none".to_string()
        } else {
            format!("{}ms", cfg.default_deadline_ms)
        },
    );
    match &cfg.journal_path {
        Some(path) => println!("job journal: {path} (jobs survive restarts)"),
        None => println!("job journal: off (jobs are volatile; --journal <path> enables)"),
    }
    if let Some(fleet) = &server.state().fleet {
        println!(
            "fleet mode: node {} sharing caches with {} peer(s): {}",
            fleet.node_id(),
            fleet.peers().len(),
            fleet
                .peers()
                .iter()
                .map(|p| p.addr())
                .collect::<Vec<_>>()
                .join(", "),
        );
    }
    while !GOT_SIGNAL.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("signal received — draining jobs and shutting down");
    server.shutdown();
    println!("snax serve stopped");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_config() -> ServerConfig {
        ServerConfig {
            port: 0,
            workers: 2,
            cache_capacity: 8,
            queue_depth: 16,
            phase_cache_capacity: 64,
            ..ServerConfig::default()
        }
    }

    #[test]
    fn starts_on_ephemeral_port_and_shuts_down() {
        let server = Server::start(test_config()).unwrap();
        assert_ne!(server.port(), 0);
        server.shutdown();
    }

    #[test]
    fn rejects_invalid_config() {
        let bad = ServerConfig { workers: 0, ..test_config() };
        assert!(Server::start(bad).is_err());
    }

    #[test]
    fn drop_without_explicit_shutdown_is_clean() {
        let server = Server::start(test_config()).unwrap();
        drop(server);
    }

    #[test]
    fn two_servers_bind_distinct_ports() {
        let a = Server::start(test_config()).unwrap();
        let b = Server::start(test_config()).unwrap();
        assert_ne!(a.port(), b.port());
        a.shutdown();
        b.shutdown();
    }

    /// Drive `handle_connection_with` directly over a loopback socket
    /// with tiny limits; returns how long the handler ran.
    fn run_handler_against(
        limits: ConnLimits,
        client_script: impl FnOnce(TcpStream) + Send + 'static,
    ) -> Duration {
        let state = Arc::new(AppState::new(&test_config()).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handler = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let start = Instant::now();
            handle_connection_with(stream, state, limits);
            start.elapsed()
        });
        let stream = TcpStream::connect(addr).unwrap();
        let client = std::thread::spawn(move || client_script(stream));
        let elapsed = handler.join().unwrap();
        client.join().unwrap();
        elapsed
    }

    /// The slowloris bound: a client dribbling one byte at a time keeps
    /// every individual read under the idle timeout, but the wall
    /// deadline armed by the request's first byte still cuts it off.
    #[test]
    fn slowloris_dribble_is_cut_off_at_the_request_wall_deadline() {
        use std::io::Write;
        // Idle alone (2s) would never fire against a 50ms dribble; only
        // the 300ms wall deadline explains a prompt cut-off.
        let limits = ConnLimits {
            idle: Duration::from_secs(2),
            request: Duration::from_millis(300),
            write: Duration::from_secs(5),
        };
        let elapsed = run_handler_against(limits, |mut stream| {
            let _ = stream
                .write_all(b"POST /simulate HTTP/1.1\r\ncontent-length: 1000\r\n\r\n");
            for _ in 0..40 {
                if stream.write_all(b"x").is_err() {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        });
        assert!(
            elapsed < Duration::from_millis(1500),
            "handler held a dribbling connection for {elapsed:?} (wall deadline is 300ms)"
        );
    }

    #[test]
    fn idle_connection_is_closed_by_the_idle_timeout() {
        let limits = ConnLimits {
            idle: Duration::from_millis(150),
            request: Duration::from_secs(5),
            write: Duration::from_secs(5),
        };
        // Client connects and sends nothing at all.
        let elapsed = run_handler_against(limits, |stream| {
            std::thread::sleep(Duration::from_millis(400));
            drop(stream);
        });
        assert!(
            elapsed < Duration::from_millis(1000),
            "idle connection held for {elapsed:?} (idle timeout is 150ms)"
        );
    }
}
