//! Minimal HTTP/1.1 framing — request parsing and response
//! serialization over any `BufRead`/`Write`, so the whole layer unit
//! tests against in-memory cursors without sockets (no hyper/axum in
//! this vendored environment; the service shape follows the same
//! health/metrics/graceful-shutdown conventions).
//!
//! Supported subset: request line + headers + `Content-Length` bodies,
//! keep-alive by default (HTTP/1.1 semantics), explicit `Connection:
//! close`. Chunked transfer encoding is rejected with 400. Hard limits
//! bound header and body sizes so a misbehaving client cannot balloon
//! memory.

use std::io::{BufRead, Read, Write};

/// Upper bound on the total header section (request line included).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Upper bound on a request body.
pub const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

/// Why a request could not be read.
#[derive(Debug)]
pub enum HttpError {
    /// Socket-level failure (reset, timeout): close the connection
    /// quietly.
    Io(std::io::Error),
    /// Protocol violation: answer 400 and close.
    Malformed(String),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Io(e) => write!(f, "i/o error: {e}"),
            HttpError::Malformed(m) => write!(f, "malformed request: {m}"),
        }
    }
}

impl std::error::Error for HttpError {}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> Self {
        HttpError::Io(e)
    }
}

fn malformed(msg: impl Into<String>) -> HttpError {
    HttpError::Malformed(msg.into())
}

/// One parsed request. Header names are lowercased at parse time.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query string (empty when absent).
    pub query: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 defaults to keep-alive; only an explicit
    /// `Connection: close` opts out.
    pub fn keep_alive(&self) -> bool {
        !matches!(self.header("connection"), Some(c) if c.eq_ignore_ascii_case("close"))
    }
}

/// Read one line (through `\n`) in bulk via the read buffer, bounded by
/// [`MAX_HEADER_BYTES`]. Returns the line without the trailing CRLF/LF,
/// or `None` at EOF before any byte.
fn read_line<R: BufRead>(r: &mut R) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    let n = r
        .by_ref()
        .take(MAX_HEADER_BYTES as u64 + 2)
        .read_until(b'\n', &mut buf)
        .map_err(HttpError::Io)?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
    }
    if buf.len() > MAX_HEADER_BYTES {
        return Err(malformed("header line too long"));
    }
    String::from_utf8(buf).map(Some).map_err(|_| malformed("non-UTF-8 header"))
}

/// Read the header block (until the blank line): lowercased names,
/// trimmed values, total size bounded. Shared by the server-side
/// request reader and the client-side response reader.
fn read_headers<R: BufRead>(r: &mut R) -> Result<Vec<(String, String)>, HttpError> {
    let mut headers = Vec::new();
    let mut total_bytes = 0usize;
    loop {
        let line = read_line(r)?.ok_or_else(|| malformed("eof inside headers"))?;
        if line.is_empty() {
            return Ok(headers);
        }
        total_bytes += line.len();
        if total_bytes > MAX_HEADER_BYTES {
            return Err(malformed("header section too large"));
        }
        let (name, value) =
            line.split_once(':').ok_or_else(|| malformed(format!("bad header '{line}'")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
}

fn content_length(headers: &[(String, String)]) -> Result<usize, HttpError> {
    match headers.iter().find(|(k, _)| k == "content-length") {
        Some((_, v)) => {
            v.parse::<usize>().map_err(|_| malformed(format!("bad content-length '{v}'")))
        }
        None => Ok(0),
    }
}

/// Read the next request off `r`. `Ok(None)` means the peer closed the
/// connection cleanly between requests.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>, HttpError> {
    let Some(request_line) = read_line(r)? else {
        return Ok(None);
    };
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| malformed("empty request line"))?.to_string();
    let target = parts.next().ok_or_else(|| malformed("missing request target"))?;
    let version = parts.next().ok_or_else(|| malformed("missing HTTP version"))?;
    if parts.next().is_some() || !version.starts_with("HTTP/1.") {
        return Err(malformed(format!("bad request line '{request_line}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let headers = read_headers(r)?;
    if headers.iter().any(|(k, _)| k == "transfer-encoding") {
        return Err(malformed("chunked transfer encoding is not supported"));
    }
    let body_len = content_length(&headers)?;
    if body_len > MAX_BODY_BYTES {
        return Err(malformed(format!("body of {body_len} bytes exceeds limit")));
    }
    let mut body = vec![0u8; body_len];
    r.read_exact(&mut body)?;
    Ok(Some(Request { method, path, query, headers, body }))
}

/// A response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    /// Extra headers beyond Content-Type/Content-Length.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "application/json",
            headers: Vec::new(),
            body: body.into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8",
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Self {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, status_text(self.status))?;
        write!(w, "Content-Type: {}\r\n", self.content_type)?;
        write!(w, "Content-Length: {}\r\n", self.body.len())?;
        for (name, value) in &self.headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

// ---------------------------------------------------------------------------
// Client side — used by the loopback integration tests and the
// serve_loadgen example (and handy for manual poking from other tools).
// ---------------------------------------------------------------------------

/// Write one client request. An empty body still sends
/// `Content-Length: 0` so the server never waits for more bytes.
pub fn write_request<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(w, "{method} {path} HTTP/1.1\r\n")?;
    w.write_all(b"Host: snax\r\n")?;
    if !keep_alive {
        w.write_all(b"Connection: close\r\n")?;
    }
    if !body.is_empty() {
        w.write_all(b"Content-Type: application/json\r\n")?;
    }
    write!(w, "Content-Length: {}\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()
}

/// Read one response: `(status, headers, body)`. Header names are
/// lowercased, bodies are framed by `Content-Length` (the only framing
/// [`Response::write_to`] emits).
#[allow(clippy::type_complexity)]
pub fn read_response<R: BufRead>(
    r: &mut R,
) -> Result<(u16, Vec<(String, String)>, Vec<u8>), HttpError> {
    let status_line = read_line(r)?.ok_or_else(|| malformed("eof before status line"))?;
    let mut parts = status_line.split_whitespace();
    let version = parts.next().ok_or_else(|| malformed("empty status line"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(malformed(format!("bad status line '{status_line}'")));
    }
    let status = parts
        .next()
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| malformed("bad status code"))?;
    let headers = read_headers(r)?;
    let mut body = vec![0u8; content_length(&headers)?];
    r.read_exact(&mut body)?;
    Ok((status, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>, HttpError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.query, "");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
        assert!(req.keep_alive());
    }

    #[test]
    fn parses_post_with_content_length_body() {
        let req = parse(
            "POST /simulate?x=1 HTTP/1.1\r\nContent-Length: 4\r\nConnection: close\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/simulate");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.body, b"abcd");
        assert!(!req.keep_alive());
    }

    #[test]
    fn header_names_are_case_insensitive() {
        let req = parse("GET / HTTP/1.1\r\nX-Thing: Value\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.header("x-thing"), Some("Value"));
        assert_eq!(req.header("X-THING"), Some("Value"));
    }

    #[test]
    fn bare_lf_line_endings_are_tolerated() {
        let req = parse("GET / HTTP/1.1\nHost: x\n\n").unwrap().unwrap();
        assert_eq!(req.header("host"), Some("x"));
    }

    #[test]
    fn eof_between_requests_is_clean_close() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn malformed_requests_are_rejected() {
        for raw in [
            "GET\r\n\r\n",                                    // no target
            "GET /\r\n\r\n",                                  // no version
            "GET / SPDY/9\r\n\r\n",                           // wrong protocol
            "GET / HTTP/1.1 extra\r\n\r\n",                   // trailing junk
            "GET / HTTP/1.1\r\nBadHeader\r\n\r\n",            // no colon
            "POST / HTTP/1.1\r\nContent-Length: pony\r\n\r\n", // bad length
            "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", // chunked
            "GET / HTTP/1.1\r\nHost: x\r\n",                  // eof inside headers
        ] {
            let err = parse(raw).unwrap_err();
            assert!(matches!(err, HttpError::Malformed(_)), "{raw:?} -> {err}");
        }
    }

    #[test]
    fn truncated_body_is_io_error() {
        let err = parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap_err();
        assert!(matches!(err, HttpError::Io(_)), "{err}");
    }

    #[test]
    fn oversized_body_is_rejected_up_front() {
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let err = parse(&raw).unwrap_err();
        assert!(matches!(err, HttpError::Malformed(_)), "{err}");
    }

    #[test]
    fn response_roundtrips_through_client_reader() {
        let resp = Response::json(200, "{\"ok\":true}".into()).with_header("X-Snax-Cache", "hit");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let (status, headers, body) = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
        assert_eq!(
            headers.iter().find(|(k, _)| k == "x-snax-cache").map(|(_, v)| v.as_str()),
            Some("hit")
        );
        assert_eq!(
            headers.iter().find(|(k, _)| k == "content-type").map(|(_, v)| v.as_str()),
            Some("application/json")
        );
    }

    #[test]
    fn request_writer_frames_empty_and_nonempty_bodies() {
        let mut wire = Vec::new();
        write_request(&mut wire, "GET", "/metrics", b"", false).unwrap();
        let req = read_request(&mut Cursor::new(wire)).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert!(!req.keep_alive());

        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/simulate", b"{}", true).unwrap();
        let req = read_request(&mut Cursor::new(wire)).unwrap().unwrap();
        assert_eq!(req.body, b"{}");
        assert!(req.keep_alive());
    }

    #[test]
    fn two_pipelined_requests_parse_sequentially() {
        let raw = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes().to_vec());
        let a = read_request(&mut cur).unwrap().unwrap();
        let b = read_request(&mut cur).unwrap().unwrap();
        assert_eq!((a.path.as_str(), b.path.as_str()), ("/a", "/b"));
        assert!(read_request(&mut cur).unwrap().is_none());
    }
}
