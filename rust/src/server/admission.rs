//! Admission control for the heavy endpoints: per-client token-bucket
//! quotas and a three-state circuit breaker (DESIGN.md §11).
//!
//! Both mechanisms shed *before* work reaches the pool, with a
//! `Retry-After` hint so well-behaved clients back off instead of
//! retry-storming:
//!
//! * **quota** (`429`) — a token bucket per `X-Snax-Client`, refilled
//!   at `quota_rps`, capped at the burst size. Protects tenants from
//!   each other.
//! * **breaker** (`503`) — closed → open on a failure-rate window or a
//!   queue-occupancy watermark; open → half-open after a cool-down;
//!   half-open admits a couple of probe requests and either closes (all
//!   probes succeed) or re-opens (any probe fails). Protects the
//!   service from itself: when jobs are panicking or the queue is
//!   drowning, fast 503s beat slow 500s.
//!
//! Exactly-once accounting contract: every request admitted past
//! [`Admission::admit`] must call [`Admission::record_outcome`] exactly
//! once (success = final HTTP status < 500). Half-open probe slots are
//! reclaimed by that call, so a missed call would wedge the breaker in
//! half-open.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;

/// Sliding outcome-window length driving the failure-rate signal.
const WINDOW: usize = 16;
/// Minimum samples in the window before the failure rate can trip.
const MIN_SAMPLES: usize = 8;
/// Failure fraction at which the breaker opens.
const FAIL_RATE: f64 = 0.5;
/// Queue occupancy (len/depth) at which admission sheds and records a
/// pressure failure — the breaker opens *before* the queue is full.
const QUEUE_WATERMARK: f64 = 0.85;
/// Probe requests admitted while half-open. Shared with the per-peer
/// health trackers in [`super::peer`], which run the same machine.
pub(crate) const HALF_OPEN_PROBES: u32 = 2;
/// Cap on tracked quota clients (drop-all reset beyond it; a client
/// that was pruned just starts from a full bucket).
const MAX_QUOTA_CLIENTS: usize = 4096;

/// Why a request was shed. Carries the `Retry-After` hint in seconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shed {
    /// Per-client token bucket empty → 429.
    Quota { retry_after_s: u64 },
    /// Breaker open (or half-open probes exhausted) → 503.
    Breaker { retry_after_s: u64 },
    /// Queue occupancy past the watermark → 503.
    Queue { retry_after_s: u64 },
}

impl Shed {
    pub fn reason(&self) -> &'static str {
        match self {
            Shed::Quota { .. } => "quota",
            Shed::Breaker { .. } => "breaker",
            Shed::Queue { .. } => "queue",
        }
    }

    pub fn retry_after_s(&self) -> u64 {
        match *self {
            Shed::Quota { retry_after_s }
            | Shed::Breaker { retry_after_s }
            | Shed::Queue { retry_after_s } => retry_after_s,
        }
    }
}

/// The three-state breaker. One instance guards the whole service
/// (here); [`super::peer`] runs one per fleet peer so a flapping peer
/// is ejected from the ring and lazily probed back — same transitions,
/// different blast radius.
#[derive(Clone, Copy)]
pub(crate) enum BreakerState {
    Closed,
    Open { until: Instant },
    HalfOpen { inflight: u32, successes: u32 },
}

pub(crate) struct BreakerInner {
    pub(crate) state: BreakerState,
    /// Recent outcomes (true = success), newest at the back.
    pub(crate) window: VecDeque<bool>,
}

impl BreakerInner {
    pub(crate) fn new() -> Self {
        BreakerInner {
            state: BreakerState::Closed,
            window: VecDeque::with_capacity(WINDOW),
        }
    }
}

struct Bucket {
    tokens: f64,
    last_refill: Instant,
}

struct QuotaInner {
    buckets: HashMap<String, Bucket>,
}

/// The admission layer. One per [`super::api::AppState`].
pub struct Admission {
    quota_rps: u32,
    quota_burst: f64,
    open_for: Duration,
    quota: Option<Mutex<QuotaInner>>,
    breaker: Option<Mutex<BreakerInner>>,
    shed_quota: AtomicU64,
    shed_breaker: AtomicU64,
    shed_queue: AtomicU64,
}

impl Admission {
    pub fn new(cfg: &ServerConfig) -> Self {
        let quota = (cfg.quota_rps > 0)
            .then(|| Mutex::new(QuotaInner { buckets: HashMap::new() }));
        let breaker = cfg.breaker.then(|| Mutex::new(BreakerInner::new()));
        let burst = if cfg.quota_burst > 0 {
            cfg.quota_burst
        } else {
            cfg.quota_rps.saturating_mul(2).max(1)
        };
        Admission {
            quota_rps: cfg.quota_rps,
            quota_burst: f64::from(burst),
            open_for: Duration::from_millis(cfg.breaker_open_ms.max(1)),
            quota,
            breaker,
            shed_quota: AtomicU64::new(0),
            shed_breaker: AtomicU64::new(0),
            shed_queue: AtomicU64::new(0),
        }
    }

    /// Admit or shed one heavy request. `queue_len`/`queue_depth` feed
    /// the occupancy watermark. On `Err` the shed counter has already
    /// been bumped; on `Ok` the caller owes exactly one
    /// [`record_outcome`](Self::record_outcome).
    pub fn admit(
        &self,
        client: &str,
        queue_len: usize,
        queue_depth: usize,
    ) -> Result<(), Shed> {
        if let Some(quota) = &self.quota {
            if let Some(shed) = self.check_quota(quota, client, Instant::now()) {
                self.note_shed(&shed);
                return Err(shed);
            }
        }
        let Some(breaker) = &self.breaker else { return Ok(()) };
        let mut b = breaker.lock().unwrap();
        let now = Instant::now();
        advance(&mut b, now);
        match b.state {
            BreakerState::Closed => {
                let watermark =
                    (queue_depth as f64 * QUEUE_WATERMARK).ceil().max(1.0) as usize;
                if queue_len >= watermark {
                    // Pressure shed counts as a failure: a sustained
                    // near-full queue opens the breaker before the pool
                    // saturates outright.
                    push_outcome(&mut b, false, now, self.open_for);
                    let shed = Shed::Queue { retry_after_s: 1 };
                    drop(b);
                    self.note_shed(&shed);
                    return Err(shed);
                }
                Ok(())
            }
            BreakerState::Open { until } => {
                let shed = Shed::Breaker {
                    retry_after_s: retry_after(until, now),
                };
                drop(b);
                self.note_shed(&shed);
                Err(shed)
            }
            BreakerState::HalfOpen { inflight, successes } => {
                if inflight >= HALF_OPEN_PROBES {
                    let shed = Shed::Breaker { retry_after_s: 1 };
                    drop(b);
                    self.note_shed(&shed);
                    return Err(shed);
                }
                b.state = BreakerState::HalfOpen {
                    inflight: inflight + 1,
                    successes,
                };
                Ok(())
            }
        }
    }

    /// Report the final status of an admitted request (success = the
    /// response was not a 5xx). Required exactly once per `Ok` admit.
    pub fn record_outcome(&self, success: bool) {
        let Some(breaker) = &self.breaker else { return };
        let mut b = breaker.lock().unwrap();
        let now = Instant::now();
        advance(&mut b, now);
        match b.state {
            BreakerState::HalfOpen { inflight, successes } => {
                if !success {
                    // A failed probe re-opens for a full cool-down.
                    b.state = BreakerState::Open { until: now + self.open_for };
                    b.window.clear();
                } else if successes + 1 >= HALF_OPEN_PROBES {
                    b.state = BreakerState::Closed;
                    b.window.clear();
                } else {
                    b.state = BreakerState::HalfOpen {
                        inflight: inflight.saturating_sub(1),
                        successes: successes + 1,
                    };
                }
            }
            BreakerState::Closed => push_outcome(&mut b, success, now, self.open_for),
            // Stragglers finishing after the breaker opened carry no
            // new signal — the open window already decided.
            BreakerState::Open { .. } => {}
        }
    }

    /// Breaker state as a metric value: 0 = closed (or breaker off),
    /// 1 = open, 2 = half-open.
    pub fn breaker_state(&self) -> u64 {
        let Some(breaker) = &self.breaker else { return 0 };
        let mut b = breaker.lock().unwrap();
        advance(&mut b, Instant::now());
        match b.state {
            BreakerState::Closed => 0,
            BreakerState::Open { .. } => 1,
            BreakerState::HalfOpen { .. } => 2,
        }
    }

    pub fn breaker_state_name(&self) -> &'static str {
        match self.breaker_state() {
            0 if self.breaker.is_none() => "off",
            0 => "closed",
            1 => "open",
            _ => "half-open",
        }
    }

    /// Shed counters by reason, for `/metrics`
    /// (`snax_requests_shed_total{reason=...}`).
    pub fn shed_counts(&self) -> [(&'static str, u64); 3] {
        [
            ("breaker", self.shed_breaker.load(Ordering::Relaxed)),
            ("queue", self.shed_queue.load(Ordering::Relaxed)),
            ("quota", self.shed_quota.load(Ordering::Relaxed)),
        ]
    }

    /// Count a shed decided outside `admit` (the pool's own queue-full
    /// 503 after admission raced new arrivals).
    pub fn note_queue_shed(&self) {
        self.shed_queue.fetch_add(1, Ordering::Relaxed);
    }

    fn note_shed(&self, shed: &Shed) {
        match shed {
            Shed::Quota { .. } => &self.shed_quota,
            Shed::Breaker { .. } => &self.shed_breaker,
            Shed::Queue { .. } => &self.shed_queue,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    fn check_quota(
        &self,
        quota: &Mutex<QuotaInner>,
        client: &str,
        now: Instant,
    ) -> Option<Shed> {
        let mut q = quota.lock().unwrap();
        if q.buckets.len() > MAX_QUOTA_CLIENTS {
            q.buckets.clear();
        }
        let bucket = q.buckets.entry(client.to_string()).or_insert(Bucket {
            tokens: self.quota_burst,
            last_refill: now,
        });
        let elapsed = now.saturating_duration_since(bucket.last_refill);
        bucket.last_refill = now;
        bucket.tokens = (bucket.tokens
            + elapsed.as_secs_f64() * f64::from(self.quota_rps))
        .min(self.quota_burst);
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            None
        } else {
            let deficit = 1.0 - bucket.tokens;
            let wait_s = (deficit / f64::from(self.quota_rps.max(1))).ceil() as u64;
            Some(Shed::Quota { retry_after_s: wait_s.max(1) })
        }
    }
}

/// Lazy state advance: an expired open window becomes half-open the
/// next time anyone looks.
pub(crate) fn advance(b: &mut BreakerInner, now: Instant) {
    if let BreakerState::Open { until } = b.state {
        if now >= until {
            b.state = BreakerState::HalfOpen { inflight: 0, successes: 0 };
        }
    }
}

/// Record a closed-state outcome and trip to open when the window says
/// the subject (the service here, one peer in [`super::peer`]) is
/// failing.
pub(crate) fn push_outcome(
    b: &mut BreakerInner,
    success: bool,
    now: Instant,
    open_for: Duration,
) {
    if b.window.len() >= WINDOW {
        b.window.pop_front();
    }
    b.window.push_back(success);
    let failures = b.window.iter().filter(|ok| !**ok).count();
    if b.window.len() >= MIN_SAMPLES
        && failures as f64 / b.window.len() as f64 >= FAIL_RATE
    {
        b.state = BreakerState::Open { until: now + open_for };
    }
}

fn retry_after(until: Instant, now: Instant) -> u64 {
    let remaining = until.saturating_duration_since(now);
    (remaining.as_millis() as u64).div_ceil(1000).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(breaker: bool, quota_rps: u32) -> ServerConfig {
        ServerConfig {
            breaker,
            breaker_open_ms: 50,
            quota_rps,
            ..ServerConfig::default()
        }
    }

    #[test]
    fn disabled_admission_admits_everything() {
        let adm = Admission::new(&cfg(false, 0));
        for _ in 0..100 {
            adm.admit("default", 0, 16).unwrap();
            adm.record_outcome(false);
        }
        assert_eq!(adm.breaker_state(), 0);
        assert_eq!(adm.breaker_state_name(), "off");
    }

    #[test]
    fn quota_bucket_exhausts_and_refills() {
        let adm = Admission::new(&cfg(false, 1000));
        // Burst = 2 * rps = 2000 tokens available immediately.
        let mut shed = None;
        for _ in 0..2001 {
            if let Err(s) = adm.admit("tenant-a", 0, 16) {
                shed = Some(s);
                break;
            }
        }
        let shed = shed.expect("bucket must exhaust within burst+1 requests");
        assert_eq!(shed.reason(), "quota");
        assert!(shed.retry_after_s() >= 1);
        // A different client has its own bucket.
        adm.admit("tenant-b", 0, 16).unwrap();
        adm.record_outcome(true);
        // Refill at 1000 rps: ~10ms buys ~10 tokens.
        std::thread::sleep(Duration::from_millis(20));
        adm.admit("tenant-a", 0, 16).unwrap();
        let [(_, _), (_, _), (_, quota_sheds)] = adm.shed_counts();
        assert!(quota_sheds >= 1);
    }

    #[test]
    fn breaker_trips_on_failures_and_recovers_via_half_open() {
        let adm = Admission::new(&cfg(true, 0));
        // MIN_SAMPLES consecutive failures trip it.
        for _ in 0..MIN_SAMPLES {
            adm.admit("default", 0, 16).unwrap();
            adm.record_outcome(false);
        }
        assert_eq!(adm.breaker_state(), 1);
        let shed = adm.admit("default", 0, 16).unwrap_err();
        assert_eq!(shed.reason(), "breaker");
        assert!(shed.retry_after_s() >= 1);
        // After the cool-down: half-open, limited probes.
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(adm.breaker_state(), 2);
        adm.admit("default", 0, 16).unwrap();
        adm.admit("default", 0, 16).unwrap();
        assert!(adm.admit("default", 0, 16).is_err(), "probe slots exhausted");
        // Both probes succeed → closed.
        adm.record_outcome(true);
        adm.record_outcome(true);
        assert_eq!(adm.breaker_state(), 0);
        adm.admit("default", 0, 16).unwrap();
        adm.record_outcome(true);
    }

    #[test]
    fn failed_probe_reopens() {
        let adm = Admission::new(&cfg(true, 0));
        for _ in 0..MIN_SAMPLES {
            adm.admit("default", 0, 16).unwrap();
            adm.record_outcome(false);
        }
        std::thread::sleep(Duration::from_millis(60));
        adm.admit("default", 0, 16).unwrap();
        adm.record_outcome(false);
        assert_eq!(adm.breaker_state(), 1, "failed probe must re-open");
    }

    #[test]
    fn queue_watermark_sheds_and_feeds_the_breaker() {
        let adm = Admission::new(&cfg(true, 0));
        // 14/16 ≥ 85% occupancy: shed with reason "queue"...
        let shed = adm.admit("default", 14, 16).unwrap_err();
        assert_eq!(shed.reason(), "queue");
        // ...and repeated pressure alone opens the breaker.
        for _ in 0..MIN_SAMPLES {
            let _ = adm.admit("default", 14, 16);
        }
        assert_eq!(adm.breaker_state(), 1);
        let [(_, breaker_sheds), (_, queue_sheds), _] = adm.shed_counts();
        assert!(queue_sheds >= MIN_SAMPLES as u64);
        let _ = breaker_sheds;
    }
}
