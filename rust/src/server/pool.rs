//! Fixed worker thread pool with a bounded job queue.
//!
//! Connection threads are cheap and numerous; the heavy work
//! (compile + cycle-accurate simulation) must not be. The pool caps
//! concurrent simulations at the configured worker count so the service
//! runs one job per core instead of thrashing, and the bounded queue
//! turns overload into immediate backpressure ([`SubmitError::Full`] →
//! HTTP 503) rather than unbounded memory growth.
//!
//! Shutdown is graceful: [`WorkerPool::shutdown`] stops accepting new
//! work, lets workers drain everything already queued, then joins them.
//! A panicking job is caught and counted — it must not take a worker
//! (and every later job on that worker) down with it.
//!
//! The scoped data-parallel layer ([`crate::parallel`]) shares this
//! module's sizing and shutdown discipline for *borrowing* workloads
//! (band-split kernels, sweep fan-out): same per-core sizing via
//! [`crate::parallel::default_parallelism`], and scope-join-on-return
//! as the structural analogue of drain-then-join.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

pub type Task = Box<dyn FnOnce() + Send + 'static>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — shed load.
    Full,
    /// Pool is shutting down.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full => write!(f, "job queue is full"),
            SubmitError::ShuttingDown => write!(f, "worker pool is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Inner {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
    shutdown: AtomicBool,
    depth: usize,
    executed: AtomicU64,
    panicked: AtomicU64,
}

pub struct WorkerPool {
    inner: Arc<Inner>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl WorkerPool {
    pub fn new(workers: usize, queue_depth: usize) -> Self {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            depth: queue_depth.max(1),
            executed: AtomicU64::new(0),
            panicked: AtomicU64::new(0),
        });
        let handles = (0..workers.max(1))
            .map(|i| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("snax-worker-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawning worker thread")
            })
            .collect();
        Self { inner, handles: Mutex::new(handles) }
    }

    /// Enqueue a job, or refuse immediately under backpressure.
    pub fn submit(&self, task: Task) -> Result<(), SubmitError> {
        {
            let mut queue = self.inner.queue.lock().unwrap();
            // Checked under the queue lock: workers only exit while
            // holding it (empty queue + flag), so a task accepted here
            // is guaranteed to be drained — never enqueued into a pool
            // whose workers are already gone.
            if self.inner.shutdown.load(Ordering::SeqCst) {
                return Err(SubmitError::ShuttingDown);
            }
            if queue.len() >= self.inner.depth {
                return Err(SubmitError::Full);
            }
            queue.push_back(task);
        }
        self.inner.available.notify_one();
        Ok(())
    }

    /// Jobs currently waiting (not counting ones being executed).
    pub fn queue_len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn queue_depth(&self) -> usize {
        self.inner.depth
    }

    /// Jobs completed (including ones that panicked).
    pub fn executed(&self) -> u64 {
        self.inner.executed.load(Ordering::Relaxed)
    }

    pub fn panicked(&self) -> u64 {
        self.inner.panicked.load(Ordering::Relaxed)
    }

    /// Graceful shutdown: refuse new submissions, drain the queue, join
    /// every worker. Idempotent.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.available.notify_all();
        let mut handles = self.handles.lock().unwrap();
        for handle in handles.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let task = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if let Some(task) = queue.pop_front() {
                    break Some(task);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = inner.available.wait(queue).unwrap();
            }
        };
        let Some(task) = task else { return };
        // A panic in one job must not kill the worker: the pool would
        // silently lose capacity for the rest of the process lifetime.
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)).is_err() {
            inner.panicked.fetch_add(1, Ordering::Relaxed);
        }
        inner.executed.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn executes_submitted_tasks() {
        let pool = WorkerPool::new(2, 16);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..10 {
            let counter = counter.clone();
            let tx = tx.clone();
            pool.submit(Box::new(move || {
                counter.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            }))
            .unwrap();
        }
        for _ in 0..10 {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let pool = WorkerPool::new(1, 1);
        // Block the single worker...
        let (block_tx, block_rx) = mpsc::channel::<()>();
        let (started_tx, started_rx) = mpsc::channel::<()>();
        pool.submit(Box::new(move || {
            started_tx.send(()).unwrap();
            let _ = block_rx.recv();
        }))
        .unwrap();
        started_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        // ...fill the queue...
        pool.submit(Box::new(|| {})).unwrap();
        // ...and the next submission bounces.
        assert_eq!(pool.submit(Box::new(|| {})).unwrap_err(), SubmitError::Full);
        block_tx.send(()).unwrap();
        pool.shutdown();
        assert_eq!(pool.executed(), 2);
    }

    #[test]
    fn shutdown_drains_queued_work() {
        let pool = WorkerPool::new(1, 64);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let counter = counter.clone();
            pool.submit(Box::new(move || {
                counter.fetch_add(1, Ordering::SeqCst);
            }))
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 20);
        assert_eq!(pool.executed(), 20);
        assert_eq!(pool.submit(Box::new(|| {})).unwrap_err(), SubmitError::ShuttingDown);
    }

    #[test]
    fn panicking_task_does_not_kill_the_worker() {
        let pool = WorkerPool::new(1, 16);
        pool.submit(Box::new(|| panic!("job blew up"))).unwrap();
        let (tx, rx) = mpsc::channel();
        pool.submit(Box::new(move || tx.send(()).unwrap())).unwrap();
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(pool.panicked(), 1);
    }
}
