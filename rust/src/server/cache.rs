//! Sharded, content-addressed compilation cache.
//!
//! Generic over the cached artifact: [`ProgramCache`] holds
//! single-cluster [`CompiledProgram`]s keyed by
//! [`crate::compiler::program_key`], and [`SystemCache`] holds
//! multi-cluster [`crate::compiler::CompiledSystem`]s keyed by
//! [`crate::compiler::system_key`] — either way a repeat simulation of
//! an identical workload skips the compiler entirely and goes straight
//! to the simulator with the shared `Arc`.
//!
//! Sharding bounds lock contention: each shard is an independent
//! `Mutex<HashMap>` selected by the low key bits (FNV-1a mixes well, so
//! low bits spread uniformly), and eviction is least-recently-used per
//! shard via a monotonic per-shard tick. Hit/miss/eviction counters are
//! lock-free and feed the `/metrics` endpoint.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::compiler::{CompiledProgram, CompiledSystem};

/// Single-cluster compilations, keyed by [`crate::compiler::program_key`].
pub type ProgramCache = ShardedCache<CompiledProgram>;
/// Whole-system compilations, keyed by [`crate::compiler::system_key`].
pub type SystemCache = ShardedCache<CompiledSystem>;
/// Rendered response bodies shared across a fleet, keyed by the fleet
/// body key (kind tag + content fingerprint; DESIGN.md §13). Reports
/// render deterministically, so a body computed on any node is the
/// byte-identical answer on every node.
pub type BodyCache = ShardedCache<String>;

struct Entry<T> {
    program: Arc<T>,
    last_used: u64,
}

struct Shard<T> {
    entries: HashMap<u64, Entry<T>>,
    tick: u64,
}

pub struct ShardedCache<T> {
    shards: Vec<Mutex<Shard<T>>>,
    per_shard_capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
}

impl<T> ShardedCache<T> {
    /// A cache of roughly `capacity` entries over 16 shards.
    pub fn new(capacity: usize) -> Self {
        Self::with_shards(capacity, 16)
    }

    /// Explicit shard count (tests use one shard for deterministic
    /// eviction). When the requested capacity is below the shard count,
    /// the shard count shrinks to match so the total never exceeds the
    /// request; otherwise capacity is divided across shards rounding
    /// *up*, so at least the requested number of entries fit overall
    /// (per-shard LRU can still evict early on skewed key
    /// distributions).
    pub fn with_shards(capacity: usize, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1).min(capacity.max(1));
        let per_shard_capacity = capacity.max(1).div_ceil(n_shards);
        Self {
            shards: (0..n_shards)
                .map(|_| Mutex::new(Shard { entries: HashMap::new(), tick: 0 }))
                .collect(),
            per_shard_capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: u64) -> &Mutex<Shard<T>> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    /// Look up a compiled program, counting a hit or miss and bumping
    /// LRU recency on hit.
    pub fn get(&self, key: u64) -> Option<Arc<T>> {
        let mut shard = self.shard(key).lock().unwrap();
        shard.tick += 1;
        let tick = shard.tick;
        match shard.entries.get_mut(&key) {
            Some(entry) => {
                entry.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.program.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or replace) a compiled program, evicting the shard's LRU
    /// entry when at capacity.
    pub fn insert(&self, key: u64, program: Arc<T>) {
        let mut shard = self.shard(key).lock().unwrap();
        shard.tick += 1;
        let tick = shard.tick;
        if shard.entries.len() >= self.per_shard_capacity && !shard.entries.contains_key(&key) {
            let victim =
                shard.entries.iter().min_by_key(|(_, e)| e.last_used).map(|(&k, _)| k);
            if let Some(victim) = victim {
                shard.entries.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.entries.insert(key, Entry { program, last_used: tick });
        self.insertions.fetch_add(1, Ordering::Relaxed);
    }

    /// `get` or compile-and-insert. Returns the shared program and
    /// whether it was a cache hit. Concurrent misses on the same key
    /// may both compile (last insert wins); compilation is deterministic
    /// so either result is valid — see DESIGN.md §6.3.
    pub fn get_or_insert_with(
        &self,
        key: u64,
        build: impl FnOnce() -> Result<T>,
    ) -> Result<(Arc<T>, bool)> {
        if let Some(p) = self.get(key) {
            return Ok((p, true));
        }
        let program = Arc::new(build()?);
        self.insert(key, program.clone());
        Ok((program, false))
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn insertions(&self) -> u64 {
        self.insertions.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, program_key, CompileOptions, Graph};
    use crate::config::ClusterConfig;

    /// A tiny CPU-only workload parameterized by name/seed so tests can
    /// mint distinct cache keys cheaply.
    fn tiny(name: &str, seed: u64) -> (Graph, ClusterConfig, CompileOptions) {
        let mut g = Graph::new(name);
        let x = g.add_input("x", &[8, 8], seed);
        let d = g.dense("fc", x, 8, false, 0, true, seed + 1).unwrap();
        g.mark_output(d);
        (g, ClusterConfig::fig6b(), CompileOptions::sequential())
    }

    fn compiled(name: &str, seed: u64) -> (u64, Arc<CompiledProgram>) {
        let (g, cfg, opts) = tiny(name, seed);
        let key = program_key(&g, &cfg, &opts);
        (key, Arc::new(compile(&g, &cfg, &opts).unwrap()))
    }

    #[test]
    fn hit_after_miss_and_counters() {
        let cache = ProgramCache::new(8);
        let (key, cp) = compiled("a", 1);
        assert!(cache.get(key).is_none());
        cache.insert(key, cp.clone());
        let got = cache.get(key).unwrap();
        assert!(Arc::ptr_eq(&got, &cp), "cache must share, not copy");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn hash_stability_across_clone_hits_the_same_entry() {
        let cache = ProgramCache::new(8);
        let (g, cfg, opts) = tiny("stable", 7);
        let key1 = program_key(&g, &cfg, &opts);
        let (g2, cfg2, opts2) = (g.clone(), cfg.clone(), opts.clone());
        let key2 = program_key(&g2, &cfg2, &opts2);
        assert_eq!(key1, key2);
        cache.insert(key1, Arc::new(compile(&g, &cfg, &opts).unwrap()));
        assert!(cache.get(key2).is_some());
    }

    #[test]
    fn lru_eviction_on_single_shard() {
        // Capacity 2, one shard -> inserting a third entry evicts the
        // least recently *used* one.
        let cache = ProgramCache::with_shards(2, 1);
        let (ka, a) = compiled("a", 10);
        let (kb, b) = compiled("b", 20);
        let (kc, c) = compiled("c", 30);
        cache.insert(ka, a);
        cache.insert(kb, b);
        // Touch `a` so `b` becomes LRU.
        assert!(cache.get(ka).is_some());
        cache.insert(kc, c);
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(ka).is_some(), "recently used entry survived");
        assert!(cache.get(kb).is_none(), "LRU entry evicted");
        assert!(cache.get(kc).is_some());
    }

    #[test]
    fn reinsert_at_capacity_does_not_evict_others() {
        let cache = ProgramCache::with_shards(2, 1);
        let (ka, a) = compiled("a", 40);
        let (kb, b) = compiled("b", 50);
        cache.insert(ka, a.clone());
        cache.insert(kb, b);
        cache.insert(ka, a); // replace in place
        assert_eq!(cache.evictions(), 0);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn tiny_capacity_is_honored_not_inflated_by_sharding() {
        // capacity 1 over the default 16 shards must not quietly hold
        // 16 entries.
        let cache = ProgramCache::new(1);
        let (ka, a) = compiled("cap-a", 80);
        let (kb, b) = compiled("cap-b", 90);
        cache.insert(ka, a);
        cache.insert(kb, b);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn get_or_insert_with_compiles_once_per_key() {
        let cache = ProgramCache::new(8);
        let (g, cfg, opts) = tiny("lazy", 60);
        let key = program_key(&g, &cfg, &opts);
        let (p1, hit1) =
            cache.get_or_insert_with(key, || compile(&g, &cfg, &opts)).unwrap();
        let (p2, hit2) = cache
            .get_or_insert_with(key, || panic!("second lookup must not compile"))
            .unwrap();
        assert!(!hit1);
        assert!(hit2);
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn concurrent_access_is_safe_and_counts() {
        let cache = Arc::new(ProgramCache::new(8));
        let (key, cp) = compiled("conc", 70);
        cache.insert(key, cp);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let cache = cache.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        assert!(cache.get(key).is_some());
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cache.hits(), 800);
    }
}
