//! Consistent-hash ring for fleet mode (DESIGN.md §13).
//!
//! Maps 64-bit content-addressed fingerprints (program/system keys and
//! the fleet body keys derived from them) onto fleet members so every
//! node agrees, without coordination, on which peer owns which cache
//! entry. Each member contributes [`VNODES`] virtual points hashed from
//! `member#replica` with the same FNV-1a used by the fingerprint layer,
//! so placement is a pure function of the sorted member list — two
//! nodes configured with the same `--peers` set compute identical
//! ownership no matter the order the addresses were listed in.
//!
//! [`Ring::owner_where`] walks clockwise past members a health filter
//! rejects, which gives the two properties the fleet layer leans on:
//!
//! * ejecting a member reassigns only the keys that member owned (the
//!   survivors' keys do not move), and the reassignment is exactly what
//!   a ring built without that member would have produced;
//! * a member joining (or probing back in) claims only the keys it now
//!   owns — everything else stays put, so rejoin is a cache-locality
//!   event, not a correctness event.

use crate::compiler::fingerprint::Fnv1a;

/// Virtual points per member. 64 keeps the ownership split within a few
/// percent of even for small fleets while the sorted point list stays
/// tiny (a fleet of 16 nodes is 1024 points).
const VNODES: u32 = 64;

fn vnode_point(member: &str, replica: u32) -> u64 {
    let mut h = Fnv1a::new();
    h.write_bytes(member.as_bytes());
    // Fixed-width replica suffix (with a separator byte outside UTF-8's
    // single-byte range) so members that are prefixes of each other
    // cannot alias points.
    h.write_bytes(&[0xff]);
    h.write_bytes(&replica.to_le_bytes());
    h.finish()
}

/// Deterministic consistent-hash ring over member address strings.
pub struct Ring {
    /// Sorted, deduplicated member list; point indices refer into it.
    members: Vec<String>,
    /// `(point hash, member index)` sorted by hash (ties broken by
    /// index, which is itself deterministic because members are sorted).
    points: Vec<(u64, u32)>,
}

impl Ring {
    /// Build a ring from member addresses. Order and duplicates do not
    /// matter: the list is sorted and deduplicated so every node in a
    /// fleet derives the same ring from the same membership set.
    pub fn new(members: impl IntoIterator<Item = String>) -> Ring {
        let mut members: Vec<String> = members.into_iter().collect();
        members.sort();
        members.dedup();
        let mut points = Vec::with_capacity(members.len() * VNODES as usize);
        for (idx, member) in members.iter().enumerate() {
            for replica in 0..VNODES {
                points.push((vnode_point(member, replica), idx as u32));
            }
        }
        points.sort_unstable();
        Ring { members, points }
    }

    pub fn members(&self) -> &[String] {
        &self.members
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// The member owning `key`: the first virtual point clockwise from
    /// the key's position.
    pub fn owner(&self, key: u64) -> Option<&str> {
        self.owner_where(key, |_| true)
    }

    /// The first member clockwise from `key` that `alive` accepts.
    /// Skipping a dead member lands on exactly the owner a ring built
    /// without that member would pick, so ejection and rejoin move only
    /// the ejected member's keys.
    pub fn owner_where(&self, key: u64, alive: impl Fn(&str) -> bool) -> Option<&str> {
        if self.points.is_empty() {
            return None;
        }
        let start = self.points.partition_point(|&(h, _)| h < key) % self.points.len();
        let mut tried = vec![false; self.members.len()];
        for offset in 0..self.points.len() {
            let (_, idx) = self.points[(start + offset) % self.points.len()];
            let idx = idx as usize;
            if std::mem::replace(&mut tried[idx], true) {
                continue;
            }
            let member = self.members[idx].as_str();
            if alive(member) {
                return Some(member);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random key stream (splitmix64 finalizer) so
    /// the placement properties are checked over a spread of keys
    /// without any external proptest machinery.
    fn key(i: u64) -> u64 {
        let mut z = i.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect()
    }

    const N_KEYS: u64 = 4096;

    #[test]
    fn placement_is_deterministic_and_order_independent() {
        let forward = Ring::new(addrs(3));
        let mut shuffled = addrs(3);
        shuffled.reverse();
        shuffled.push(shuffled[0].clone()); // duplicate must not matter
        let backward = Ring::new(shuffled);
        assert_eq!(forward.members(), backward.members());
        for i in 0..N_KEYS {
            let k = key(i);
            assert_eq!(forward.owner(k), backward.owner(k));
        }
    }

    #[test]
    fn ownership_is_roughly_balanced() {
        let ring = Ring::new(addrs(3));
        let mut counts = std::collections::HashMap::new();
        for i in 0..N_KEYS {
            *counts.entry(ring.owner(key(i)).unwrap().to_string()).or_insert(0u64) += 1;
        }
        assert_eq!(counts.len(), 3, "every member must own some keys");
        for (member, n) in &counts {
            let share = *n as f64 / N_KEYS as f64;
            assert!(
                share > 0.10,
                "member {member} owns {share:.3} of keys — too imbalanced"
            );
        }
    }

    #[test]
    fn join_moves_only_keys_claimed_by_the_new_member() {
        let before = Ring::new(addrs(3));
        let after = Ring::new(addrs(4));
        let newcomer = "127.0.0.1:9003";
        let mut moved = 0u64;
        for i in 0..N_KEYS {
            let k = key(i);
            let owner_before = before.owner(k).unwrap();
            let owner_after = after.owner(k).unwrap();
            if owner_before != owner_after {
                moved += 1;
                assert_eq!(
                    owner_after, newcomer,
                    "a key may only move to the joining member"
                );
            }
        }
        let fraction = moved as f64 / N_KEYS as f64;
        assert!(moved > 0, "the newcomer must claim some keys");
        assert!(
            fraction < 0.45,
            "join moved {fraction:.3} of keys — expected ~1/4"
        );
    }

    #[test]
    fn leave_moves_only_the_leavers_keys() {
        let before = Ring::new(addrs(4));
        let leaver = "127.0.0.1:9003";
        let after = Ring::new(addrs(3));
        for i in 0..N_KEYS {
            let k = key(i);
            let owner_before = before.owner(k).unwrap();
            if owner_before != leaver {
                assert_eq!(
                    after.owner(k),
                    Some(owner_before),
                    "a surviving member's key must not move on leave"
                );
            }
        }
    }

    #[test]
    fn dead_owner_falls_through_to_the_shrunk_rings_owner() {
        let full = Ring::new(addrs(3));
        let dead = "127.0.0.1:9001";
        let shrunk = Ring::new(vec!["127.0.0.1:9000".into(), "127.0.0.1:9002".into()]);
        for i in 0..N_KEYS {
            let k = key(i);
            assert_eq!(
                full.owner_where(k, |m| m != dead),
                shrunk.owner(k),
                "health filter must behave like removing the member"
            );
        }
    }

    #[test]
    fn empty_and_singleton_rings() {
        assert_eq!(Ring::new(Vec::new()).owner(7), None);
        let solo = Ring::new(vec!["127.0.0.1:9000".to_string()]);
        for i in 0..64 {
            assert_eq!(solo.owner(key(i)), Some("127.0.0.1:9000"));
        }
        // Everyone dead: no owner rather than a spin.
        assert_eq!(solo.owner_where(3, |_| false), None);
    }
}
