//! Activity-based energy/power model (Fig. 9 / Table I substitution for
//! PrimeTime switching-annotated power analysis — see DESIGN.md §1).
//!
//! Event counts from the simulator ([`crate::sim::Counters`]) are
//! weighted by the per-event energies in [`super::calib`]; power is
//! energy over the run's wall-clock at the configured frequency.

use crate::config::ClusterConfig;
use crate::sim::SimReport;

use super::calib::*;

/// Energy attributed to one component over a run, in uJ.
#[derive(Debug, Clone)]
pub struct EnergyItem {
    pub component: String,
    pub uj: f64,
}

#[derive(Debug, Clone)]
pub struct EnergyBreakdown {
    pub items: Vec<EnergyItem>,
    pub total_cycles: u64,
    pub freq_mhz: u32,
}

impl EnergyBreakdown {
    pub fn total_uj(&self) -> f64 {
        self.items.iter().map(|i| i.uj).sum()
    }

    /// Average power over the run, in mW.
    pub fn avg_power_mw(&self) -> f64 {
        let seconds = self.total_cycles as f64 / (self.freq_mhz as f64 * 1e6);
        if seconds == 0.0 {
            0.0
        } else {
            self.total_uj() * 1e-6 / seconds * 1e3
        }
    }

    pub fn get(&self, component: &str) -> f64 {
        self.items
            .iter()
            .filter(|i| i.component == component)
            .map(|i| i.uj)
            .sum()
    }
}

/// Compute the energy breakdown of a finished run.
pub fn energy(report: &SimReport, cfg: &ClusterConfig) -> EnergyBreakdown {
    let c = &report.counters;
    let pj = |v: f64| v * 1e-6; // pJ -> uJ

    let accel = c.gemm_compute_cycles as f64 * PJ_GEMM_CYCLE
        + c.pool_compute_cycles as f64 * PJ_POOL_CYCLE
        + c.other_accel_cycles as f64 * PJ_OTHER_ACCEL_CYCLE;

    // Streamer energy: every bank word moved passed through an AGU+FIFO.
    let streamers = (c.bank_reads + c.bank_writes) as f64 * PJ_STREAMER_WORD;

    let spm = c.bank_reads as f64 * PJ_BANK_READ + c.bank_writes as f64 * PJ_BANK_WRITE;

    let axi = c.axi_beats as f64 * PJ_AXI_BEAT;

    let cores: u64 = c.core_busy_cycles.iter().sum();
    let cores = cores as f64 * PJ_CORE_CYCLE + c.csr_writes as f64 * PJ_CSR_WRITE;

    let idle = report.total_cycles as f64 * PJ_IDLE_CYCLE;

    EnergyBreakdown {
        items: vec![
            EnergyItem { component: "accelerators".into(), uj: pj(accel) },
            EnergyItem { component: "streamers".into(), uj: pj(streamers) },
            EnergyItem { component: "spm".into(), uj: pj(spm) },
            EnergyItem { component: "axi_dma".into(), uj: pj(axi) },
            EnergyItem { component: "cores".into(), uj: pj(cores) },
            EnergyItem { component: "clock_leakage".into(), uj: pj(idle) },
        ],
        total_cycles: report.total_cycles,
        freq_mhz: cfg.freq_mhz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Counters;

    fn fake_report(cycles: u64, counters: Counters) -> SimReport {
        SimReport { total_cycles: cycles, counters, ..Default::default() }
    }

    #[test]
    fn busy_gemm_run_is_accel_dominated() {
        // A run resembling pipelined Fig. 6a: accelerator-heavy.
        let c = Counters {
            gemm_compute_cycles: 40_000,
            pool_compute_cycles: 2_000,
            bank_reads: 700_000,
            bank_writes: 100_000,
            axi_beats: 3_000,
            csr_writes: 2_000,
            core_busy_cycles: vec![30_000, 30_000],
            ..Default::default()
        };
        let e = energy(&fake_report(60_000, c), &ClusterConfig::fig6d());
        // Fig. 9 ordering: accelerators+streamers majority, then SPM,
        // then cores.
        let accel_stream = e.get("accelerators") + e.get("streamers");
        assert!(accel_stream > e.get("spm"), "{e:?}");
        assert!(e.get("spm") > e.get("cores"), "{e:?}");
        assert!(e.avg_power_mw() > 0.0);
    }

    #[test]
    fn power_scale_near_table1() {
        // Table I: ~227 mW during active operation. A fully-busy
        // pipelined run should land in the same regime (0.5x-2x).
        let c = Counters {
            gemm_compute_cycles: 50_000,
            pool_compute_cycles: 8_000,
            bank_reads: 900_000,
            bank_writes: 150_000,
            axi_beats: 5_000,
            csr_writes: 3_000,
            core_busy_cycles: vec![50_000, 50_000],
            ..Default::default()
        };
        let e = energy(&fake_report(60_000, c), &ClusterConfig::fig6d());
        let mw = e.avg_power_mw();
        assert!((100.0..500.0).contains(&mw), "power = {mw} mW");
    }

    #[test]
    fn idle_run_is_leakage_only() {
        let e = energy(
            &fake_report(1000, Counters { core_busy_cycles: vec![0], ..Default::default() }),
            &ClusterConfig::fig6b(),
        );
        assert_eq!(e.total_uj(), e.get("clock_leakage"));
    }
}
