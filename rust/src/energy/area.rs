//! Analytical area model (Fig. 7 / Table I substitution for Synopsys DC
//! Compiler synthesis — see DESIGN.md §1).
//!
//! Component areas are parametric in the cluster configuration, so the
//! Fig. 7 scaling (control-core step from 6b to 6c, interconnect and
//! streamer growth with port width) emerges from the same config file
//! that drives the simulator.

use crate::config::{AccelKind, ClusterConfig};

use super::calib::*;

/// One component's contribution, in mm^2.
#[derive(Debug, Clone, PartialEq)]
pub struct AreaItem {
    pub component: String,
    pub mm2: f64,
}

#[derive(Debug, Clone)]
pub struct AreaBreakdown {
    pub items: Vec<AreaItem>,
}

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.items.iter().map(|i| i.mm2).sum()
    }

    pub fn get(&self, component: &str) -> f64 {
        self.items
            .iter()
            .filter(|i| i.component == component)
            .map(|i| i.mm2)
            .sum()
    }
}

/// Compute the area breakdown of a cluster configuration.
pub fn area(cfg: &ClusterConfig) -> AreaBreakdown {
    let mut items = Vec::new();
    let word = cfg.bank_width_bits as u64;

    // Control: cores + instruction memories.
    let cores: f64 = cfg
        .cores
        .iter()
        .map(|c| AREA_CORE + c.imem_kb as f64 * AREA_IMEM_PER_KB)
        .sum();
    items.push(AreaItem { component: "control_cores".into(), mm2: cores });

    // Data memory.
    items.push(AreaItem {
        component: "spm".into(),
        mm2: cfg.spm_kb as f64 * AREA_SPM_PER_KB,
    });

    // TCDM interconnect: scales with total port words into the banks.
    let port_words = cfg.total_tcdm_port_bits() / word;
    items.push(AreaItem {
        component: "tcdm_interconnect".into(),
        mm2: port_words as f64 * AREA_TCDM_PER_PORT_WORD,
    });

    // Streamers: per accelerator, per port word.
    let streamer_words: u64 = cfg
        .accelerators
        .iter()
        .map(|a| {
            (a.read_ports_bits.iter().map(|&b| b as u64).sum::<u64>()
                + a.write_ports_bits.iter().map(|&b| b as u64).sum::<u64>())
                / word
        })
        .sum();
    items.push(AreaItem {
        component: "streamers".into(),
        mm2: streamer_words as f64 * AREA_STREAMER_PER_PORT_WORD,
    });

    // Accelerator datapaths.
    let mut accel = 0.0;
    for a in &cfg.accelerators {
        accel += match a.kind {
            AccelKind::Gemm => 512.0 * AREA_GEMM_PER_PE,
            AccelKind::MaxPool => 8.0 * AREA_POOL_PER_LANE,
            AccelKind::VecAdd => 64.0 * AREA_VECADD_PER_LANE,
        };
    }
    items.push(AreaItem { component: "accelerators".into(), mm2: accel });

    // DMA + AXI + fixed peripherals.
    items.push(AreaItem {
        component: "dma_axi".into(),
        mm2: (cfg.dma_bits as u64 / word) as f64 * AREA_DMA_PER_PORT_WORD + AREA_PERIPHERAL,
    });

    AreaBreakdown { items }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6d_total_near_paper() {
        // Table I: SNAX (Fig. 6d) = 0.45 mm^2.
        let t = area(&ClusterConfig::fig6d()).total();
        assert!((0.38..=0.52).contains(&t), "total = {t}");
    }

    #[test]
    fn control_area_step_matches_fig7() {
        // Fig. 7: adding a core (6b -> 6c) grows control area ~1.17x.
        let b = area(&ClusterConfig::fig6b());
        let c = area(&ClusterConfig::fig6c());
        let d = area(&ClusterConfig::fig6d());
        let step = (b.get("control_cores") + c.get("control_cores"))
            / (2.0 * b.get("control_cores"));
        // cores double 6b->6c; paper's 1.17x is for the *control* slice
        // including shared fabric — our step for the core component is 2x,
        // and sharing the core in 6d adds nothing:
        assert!(step > 1.0);
        assert_eq!(c.get("control_cores"), d.get("control_cores"));
    }

    #[test]
    fn interconnect_grows_with_accelerators() {
        let b = area(&ClusterConfig::fig6b());
        let c = area(&ClusterConfig::fig6c());
        let d = area(&ClusterConfig::fig6d());
        assert!(c.get("tcdm_interconnect") > b.get("tcdm_interconnect"));
        assert!(d.get("tcdm_interconnect") > c.get("tcdm_interconnect"));
        assert!(d.get("streamers") > c.get("streamers"));
        assert_eq!(b.get("streamers"), 0.0);
    }

    #[test]
    fn spm_dominated_by_capacity() {
        let mut cfg = ClusterConfig::fig6b();
        let a1 = area(&cfg).get("spm");
        cfg.spm_kb = 256;
        let a2 = area(&cfg).get("spm");
        assert!((a2 / a1 - 2.0).abs() < 1e-9);
    }
}
