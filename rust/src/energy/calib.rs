//! Calibration constants for the timing, area, and energy models.
//!
//! Every number here is anchored to a figure the paper (or its cited
//! sources) reports; the anchors are documented inline. The models built
//! on these constants reproduce the *relative* behaviour of the paper's
//! TSMC-16 nm measurements — component scaling (Fig. 7), cycle
//! distributions (Fig. 8), power breakdown (Fig. 9), and the Table I
//! workload numbers — not absolute silicon truth.

// ---------------------------------------------------------------------------
// RV32I(M) software-kernel cost model (cycles per elementary operation)
//
// Anchor: a single-issue in-order RV32IM core executing int8 kernels.
// A naive conv inner loop costs ~9 cycles/MAC (2 loads with address
// arithmetic, mul, add, loop bookkeeping); a unit-stride dot product
// with word loads and unrolling reaches ~3 cycles/MAC; pooling pays a
// load+compare+select per window element plus indexing. These land the
// baseline cycle distribution of Fig. 8 (convolution dominating ~99%).
// ---------------------------------------------------------------------------

/// Cycles per int8 MAC of a convolution on the management core.
pub const CPU_MAC_CONV: u64 = 9;
/// Cycles per int8 MAC of a dense/FC layer (unit-stride, unrolled).
pub const CPU_MAC_FC: u64 = 3;
/// Cycles per window element of max-pooling.
pub const CPU_POOL_OP: u64 = 8;
/// Cycles per element of int8 elementwise ops (relu, residual add —
/// word-packed, ~4 lanes per load/store pair).
pub const CPU_ELEM: u64 = 2;
/// Cycles per element of global average pooling (load + add).
pub const CPU_AVG: u64 = 3;
/// Fixed per-kernel software overhead (prologue, loop setup, pointers).
pub const CPU_KERNEL_OVERHEAD: u64 = 150;

// ---------------------------------------------------------------------------
// Area model (mm^2, TSMC 16 nm @ 800 MHz)
//
// Anchor: Fig. 7 / Table I — the full Fig. 6d cluster is 0.45 mm^2 with
// 128 KiB SPM, two RV32I cores, GeMM (512 PEs) + max-pool accelerators,
// their streamers, the TCDM interconnect, and peripherals. Components
// are sized so (a) the Fig. 6d total lands at ~0.45 mm^2, (b) the
// control-area step from Fig. 6b to 6c is ~1.17x, and (c) interconnect
// area scales with total port width as Fig. 7 shows.
// ---------------------------------------------------------------------------

/// SRAM macro area per KiB (dense 16 nm single-port SRAM).
pub const AREA_SPM_PER_KB: f64 = 0.0012;
/// One RV32I management core (logic only).
pub const AREA_CORE: f64 = 0.009;
/// Instruction memory per KiB.
pub const AREA_IMEM_PER_KB: f64 = 0.0011;
/// TCDM interconnect per 64-bit port-to-bank crossbar lane.
pub const AREA_TCDM_PER_PORT_WORD: f64 = 0.0011;
/// Data streamer per 64-bit lane (AGU + FIFO slice).
pub const AREA_STREAMER_PER_PORT_WORD: f64 = 0.0016;
/// GeMM PE (int8 MAC + accumulator slice).
pub const AREA_GEMM_PER_PE: f64 = 0.00014;
/// Max-pool lane.
pub const AREA_POOL_PER_LANE: f64 = 0.0008;
/// Vector-add lane (custom accelerator example).
pub const AREA_VECADD_PER_LANE: f64 = 0.00012;
/// DMA engine + AXI port per 64 bits of width.
pub const AREA_DMA_PER_PORT_WORD: f64 = 0.0012;
/// Fixed peripherals (AXI network, barrier unit, CSR fabric).
pub const AREA_PERIPHERAL: f64 = 0.018;

// ---------------------------------------------------------------------------
// Energy model (pJ per event, 0.8 V 16 nm)
//
// Anchors: Table I — ToyADMOS (Deep AutoEncoder) at ~5.16 uJ and
// ResNet-8 at ~28 uJ on the Fig. 6d cluster; Fig. 9 — accelerators +
// streamers consume the majority of parallel-execution power, followed
// by data memory (SPM banks), peripherals/interconnect, then cores.
// ResNet-8's ~12.5M MACs at ~28 uJ imply ~2.2 pJ of *system* energy per
// MAC, split across PE datapath, SPM traffic, and streaming as below.
// ---------------------------------------------------------------------------

/// One GeMM PE-array cycle (512 int8 MACs): datapath + local registers.
pub const PJ_GEMM_CYCLE: f64 = 320.0;
/// One max-pool unit cycle (8 lanes).
pub const PJ_POOL_CYCLE: f64 = 18.0;
/// One custom-accel (vec-add) cycle.
pub const PJ_OTHER_ACCEL_CYCLE: f64 = 20.0;
/// One 64-bit SPM bank read.
pub const PJ_BANK_READ: f64 = 8.5;
/// One 64-bit SPM bank write.
pub const PJ_BANK_WRITE: f64 = 9.5;
/// One streamer beat (AGU + FIFO push/pop), per 64-bit word moved.
pub const PJ_STREAMER_WORD: f64 = 3.0;
/// One 64-byte AXI beat (off-cluster wires + protocol).
pub const PJ_AXI_BEAT: f64 = 95.0;
/// One management-core active cycle.
pub const PJ_CORE_CYCLE: f64 = 11.0;
/// One CSR write (control fabric).
pub const PJ_CSR_WRITE: f64 = 2.0;
/// Cluster leakage + clock tree per cycle (everything powered).
pub const PJ_IDLE_CYCLE: f64 = 24.0;

/// Clock frequency anchor (Table I: 800 MHz).
pub const FREQ_MHZ_DEFAULT: u32 = 800;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6d_area_lands_near_paper() {
        // Coarse sanity: the component sum for the Fig. 6d configuration
        // must land near the paper's 0.45 mm^2 (checked precisely in
        // energy::area tests).
        let spm = 128.0 * AREA_SPM_PER_KB;
        let cores = 2.0 * (AREA_CORE + 8.0 * AREA_IMEM_PER_KB);
        let gemm = 512.0 * AREA_GEMM_PER_PE;
        let pool = 8.0 * AREA_POOL_PER_LANE;
        let streamers = ((512 + 512 + 2048 + 512 + 512) / 64) as f64
            * AREA_STREAMER_PER_PORT_WORD;
        let tcdm = ((512 + 512 + 2048 + 512 + 512 + 64 + 64 + 512) / 64) as f64
            * AREA_TCDM_PER_PORT_WORD;
        let dma = (512 / 64) as f64 * AREA_DMA_PER_PORT_WORD;
        let total = spm + cores + gemm + pool + streamers + tcdm + dma + AREA_PERIPHERAL;
        assert!((0.35..0.55).contains(&total), "total={total}");
    }

    #[test]
    fn resnet8_energy_scale_sane() {
        // ~12.5M MACs => ~24.4k GeMM cycles; datapath energy alone
        // should be a fraction of the ~28 uJ Table I total.
        let datapath_uj = 24_400.0 * PJ_GEMM_CYCLE * 1e-6;
        assert!(datapath_uj > 2.0 && datapath_uj < 20.0, "{datapath_uj}");
    }
}
