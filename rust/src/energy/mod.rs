//! Area and energy models (substitutes for the paper's TSMC-16 nm
//! synthesis + PrimeTime flow, calibrated to its reported numbers).

pub mod area;
pub mod calib;
pub mod power;

pub use area::{area, AreaBreakdown};
pub use power::{energy, EnergyBreakdown};
