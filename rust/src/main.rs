//! `snax` — the leader binary: compile + simulate workloads on SNAX
//! cluster configurations, verify against the AOT PJRT artifacts, and
//! print evaluation reports.
//!
//! Hand-rolled argument parsing (no clap in this vendored environment).
//!
//! ```text
//! snax simulate --net fig6a --cluster fig6d [--pipelined] [--inferences N]
//!               [--engine event|exact] (event-driven fast engine vs.
//!               the exact per-cycle reference; identical reports)
//! snax simulate --net resnet8 --system soc2 --partition pipeline|data
//!               [--threads N] (multi-cluster SoC: partition pass +
//!               shared-NoC contention simulation; independent members
//!               fan out over N driver threads, byte-identical reports)
//! snax sweep    --nets fig6a,dae --clusters fig6b,fig6c,fig6d
//!               [--pipelined] [--inferences N] [--engine event|exact]
//!               [--threads N] [--json out.json]
//!               (batch fan-out: every net x cluster combination
//!               simulated concurrently, results in input order)
//! snax profile  --net fig6a --cluster fig6d [--system soc2] [--threads N]
//!               [--json out.json]
//!               (cycle-accounting ledger: stall-cause attribution per
//!               unit, roofline placement, per-layer spans)
//! snax serve    [--port P] [--workers N] [--cache N] [--queue N]
//!               [--deadline-ms D] [--breaker on|off] [--quota-rps R]
//! snax fig8     (the heterogeneous-acceleration cascade)
//! snax roofline --tiles 16,32,64,96,128 [--baseline]
//! snax report   (area summary for all presets)
//! snax verify   --net fig6a (sim vs golden vs PJRT artifact)
//! snax config   --preset fig6d (dump the TOML config)
//! ```

use anyhow::{bail, Context, Result};

use snax::compiler::{compile, compile_system, CompileOptions, PartitionStrategy};
use snax::config::{ClusterConfig, SystemConfig};
use snax::energy;
use snax::metrics::report::{cycles, pct, ratio, table};
use snax::metrics::roofline::RooflinePoint;
use snax::models;
use snax::models::matmul::{overlapped_program, serialized_program, MatmulWorkload};
use snax::runtime::{ArtifactStore, Tensor};
use snax::sim::{Cluster, System};

struct Args {
    cmd: String,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = std::collections::BTreeMap::new();
        let mut key: Option<String> = None;
        for a in it {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(k) = key.take() {
                    flags.insert(k, "true".into()); // boolean flag
                }
                key = Some(stripped.to_string());
            } else if let Some(k) = key.take() {
                flags.insert(k, a);
            } else {
                bail!("unexpected positional argument '{a}'");
            }
        }
        if let Some(k) = key.take() {
            flags.insert(k, "true".into());
        }
        Ok(Self { cmd, flags })
    }

    fn get(&self, k: &str, default: &str) -> String {
        self.flags.get(k).cloned().unwrap_or_else(|| default.into())
    }

    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
}

fn graph_for(name: &str) -> Result<snax::compiler::Graph> {
    models::graph_by_name(name)
}

fn cluster_for(args: &Args) -> Result<ClusterConfig> {
    let spec = args.get("cluster", "fig6d");
    if spec.ends_with(".toml") {
        ClusterConfig::from_path(std::path::Path::new(&spec))
    } else {
        ClusterConfig::preset(&spec)
    }
}

/// Shared `--pipelined` / `--inferences` / `--engine` / `--memo` /
/// `--threads` parsing for the simulate, profile, and sweep
/// subcommands. `--threads` caps *driver-level* fan-out (sweep jobs,
/// system members); each consumer divides the same budget down to
/// per-member functional-retire pools (`with_func_threads`) so nested
/// parallelism never multiplies. Reports are byte-identical at any
/// setting — threads change wall-clock only.
fn sim_options(
    args: &Args,
) -> Result<(CompileOptions, snax::sim::SimMode, bool, Option<usize>)> {
    let n: u32 = args.get("inferences", "1").parse()?;
    let opts = if args.has("pipelined") {
        CompileOptions::pipelined().with_inferences(n.max(2))
    } else {
        CompileOptions::sequential().with_inferences(n)
    };
    let mode = match args.get("engine", "event").as_str() {
        "event" => snax::sim::SimMode::Event,
        "exact" => snax::sim::SimMode::Exact,
        other => bail!("unknown engine '{other}' (expected event|exact)"),
    };
    let memo = match args.get("memo", "on").as_str() {
        "on" => true,
        "off" => false,
        other => bail!("unknown --memo '{other}' (expected on|off)"),
    };
    let threads: Option<usize> = args
        .flags
        .get("threads")
        .map(|t| t.parse().context("bad --threads"))
        .transpose()?;
    Ok((opts, mode, memo, threads))
}

fn phase_stats_json(s: &snax::sim::PhaseCacheStats) -> snax::runtime::json::Value {
    use snax::runtime::json::Value;
    Value::object([
        ("hits", Value::from(s.hits)),
        ("misses", Value::from(s.misses)),
        ("insertions", Value::from(s.insertions)),
        ("evictions", Value::from(s.evictions)),
        ("replayed_cycles", Value::from(s.replayed_cycles)),
        ("entries", Value::from(s.entries)),
    ])
}

/// Shared `--checkpoint-dir` / `--checkpoint-every` / `--resume`
/// parsing for the cluster and system simulate paths. `--resume`
/// accepts a checkpoint file or a directory (the lexicographically
/// latest `.ckpt` inside is picked, which is the newest — filenames
/// embed the zero-padded cycle).
fn checkpoint_args(
    args: &Args,
) -> Result<(Option<snax::sim::CheckpointPlan>, Option<snax::sim::Checkpoint>)> {
    let plan = match args.flags.get("checkpoint-dir") {
        Some(dir) => {
            let every: u64 = args
                .get("checkpoint-every", "8")
                .parse()
                .context("bad --checkpoint-every")?;
            Some(snax::sim::CheckpointPlan::new(dir.as_str()).every(every))
        }
        None => None,
    };
    let resume = match args.flags.get("resume") {
        Some(path) => {
            let p = std::path::Path::new(path);
            let file = if p.is_dir() {
                snax::sim::checkpoint::latest_in_dir(p)?
                    .with_context(|| format!("no checkpoint files in {path}"))?
            } else {
                p.to_path_buf()
            };
            let ck = snax::sim::checkpoint::load(&file)
                .with_context(|| format!("loading checkpoint {}", file.display()))?;
            println!("resuming from {} (cycle {})", file.display(), ck.cycle());
            Some(ck)
        }
        None => None,
    };
    Ok((plan, resume))
}

/// Resolve `--system` (preset name or .toml path), falling back to a
/// system-of-1 around `--cluster` when only `--partition` was given.
fn system_for(args: &Args) -> Result<SystemConfig> {
    match args.flags.get("system") {
        Some(spec) if spec.ends_with(".toml") => {
            SystemConfig::from_path(std::path::Path::new(spec))
        }
        Some(spec) => SystemConfig::preset(spec),
        None => Ok(SystemConfig::single(cluster_for(args)?)),
    }
}

/// `snax simulate --system ...`: compile through the partition pass and
/// run the multi-cluster system simulator.
fn cmd_simulate_system(args: &Args) -> Result<()> {
    let sys = system_for(args)?;
    let strategy = match args.flags.get("partition") {
        Some(s) => PartitionStrategy::parse(s)?,
        None => PartitionStrategy::default_for(&sys),
    };
    let g = graph_for(&args.get("net", "fig6a"))?;
    let (opts, mode, memo, threads) = sim_options(args)?;
    let (ckpt_plan, resume_ck) = checkpoint_args(args)?;
    let cs = compile_system(&g, &sys, &opts, strategy)?;
    let mut system = System::new(&sys).with_memo(memo).with_threads(threads);
    if let Some(plan) = ckpt_plan {
        system = system.with_checkpoint(plan);
    }
    let rep = match &resume_ck {
        Some(ck) => system.resume_mode(&cs.programs(), mode, ck)?,
        None => system.run_mode(&cs.programs(), mode)?,
    };
    let freq = sys.clusters[0].freq_mhz;
    println!(
        "net={} system={} partition={} clusters={} mode={:?} inferences={}",
        cs.net,
        sys.name,
        cs.plan.strategy.name(),
        sys.n_clusters(),
        opts.mode,
        cs.n_inferences()
    );
    println!(
        "total: {} cycles = {:.3} ms @ {freq} MHz",
        cycles(rep.total_cycles),
        rep.seconds(freq) * 1e3
    );
    let mut rows = Vec::new();
    for ((pp, r), cfg) in cs.plan.parts.iter().zip(&rep.clusters).zip(&sys.clusters) {
        let e = energy::energy(r, cfg);
        rows.push(vec![
            pp.cluster.clone(),
            format!("{}..{}", pp.node_range.0, pp.node_range.1),
            format!("{}", pp.n_inferences),
            cycles(r.total_cycles),
            cycles(r.counters.noc_stall_cycles),
            format!("{:.2}", e.total_uj()),
        ]);
    }
    println!(
        "{}",
        table(&["cluster", "layers", "inf", "cycles", "noc stalls", "energy uJ"], &rows)
    );
    println!(
        "noc: {} beats granted, {} denied (contention), {} barrier releases",
        rep.noc.granted, rep.noc.denied, rep.noc.barrier_releases
    );
    if let Some(path) = args.flags.get("json") {
        let body = snax::server::render_system_report(&cs, &rep);
        std::fs::write(path, body).with_context(|| format!("writing {path}"))?;
        println!("wrote system report json to {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    if args.has("system") || args.has("partition") {
        return cmd_simulate_system(args);
    }
    let cfg = cluster_for(args)?;
    let g = graph_for(&args.get("net", "fig6a"))?;
    // Single-cluster runs have no driver-level fan-out; `--threads` is
    // accepted (shared parser) and unused.
    let (opts, mode, memo, _threads) = sim_options(args)?;
    let (ckpt_plan, resume_ck) = checkpoint_args(args)?;
    let cp = compile(&g, &cfg, &opts)?;
    // Same sizing as the engine's default per-run cache — the explicit
    // handle exists only so the CLI can report hit/miss stats.
    let phase_cache = std::sync::Arc::new(snax::sim::PhaseCache::for_run());
    let mut cluster =
        Cluster::new(&cfg).with_memo(memo).with_phase_cache(phase_cache.clone());
    if let Some(plan) = ckpt_plan {
        cluster = cluster.with_checkpoint(plan);
    }
    let trace_path = args.flags.get("trace").cloned();
    let report = if let Some(path) = &trace_path {
        if resume_ck.is_some() {
            // The trace covers the whole run by construction; a resumed
            // run only re-executes the tail, so the two cannot compose.
            bail!("--trace cannot be combined with --resume");
        }
        let (report, trace) = cluster.run_traced_mode(&cp.program, mode)?;
        std::fs::write(path, trace.to_chrome_json())
            .with_context(|| format!("writing trace to {path}"))?;
        println!("wrote chrome trace ({} events) to {path}", trace.events.len());
        report
    } else if let Some(ck) = &resume_ck {
        cluster.resume_mode(&cp.program, mode, ck)?
    } else {
        cluster.run_mode(&cp.program, mode)?
    };

    println!(
        "net={} cluster={} mode={:?} inferences={}",
        g.name, cfg.name, opts.mode, opts.n_inferences
    );
    println!(
        "total: {} cycles = {:.3} ms @ {} MHz",
        cycles(report.total_cycles),
        report.seconds(cfg.freq_mhz) * 1e3,
        cfg.freq_mhz
    );
    let mut rows = Vec::new();
    for (id, stat) in &report.layers {
        rows.push(vec![
            format!("{id}"),
            stat.name.clone(),
            cycles(stat.busy_cycles),
            cycles(stat.span()),
        ]);
    }
    println!("{}", table(&["layer", "name", "busy cycles", "span"], &rows));
    let mut rows = Vec::new();
    for u in &report.units {
        rows.push(vec![
            u.name.clone(),
            cycles(u.active_cycles),
            cycles(u.compute_cycles),
            pct(u.utilization()),
            format!("{}", u.jobs),
        ]);
    }
    println!("{}", table(&["unit", "active", "compute", "util", "jobs"], &rows));
    let e = energy::energy(&report, &cfg);
    println!("energy: {:.2} uJ  avg power: {:.1} mW", e.total_uj(), e.avg_power_mw());
    let ps = phase_cache.stats();
    if memo && mode == snax::sim::SimMode::Event {
        println!(
            "phase cache: {} hits / {} misses, {} cycles replayed",
            ps.hits, ps.misses, ps.replayed_cycles
        );
    }
    if let Some(path) = args.flags.get("json") {
        // Deterministic report JSON plus the (run-local, serial, hence
        // also deterministic) phase-cache effectiveness counters.
        let body = format!(
            "{{\"report\":{},\"phase_cache\":{}}}",
            snax::server::render_report(&cp, &cfg, &report),
            phase_stats_json(&ps).to_json()
        );
        std::fs::write(path, body).with_context(|| format!("writing {path}"))?;
        println!("wrote report json to {path}");
    }
    Ok(())
}

/// Human-readable attribution table: one row per ledger row, with the
/// exhaustive category split and the dominant bottleneck cause.
fn ledger_table(lg: &snax::sim::LedgerReport) -> String {
    use snax::sim::Cat;
    let mut rows = Vec::new();
    for r in &lg.rows {
        let (cause, share) = match r.bottleneck() {
            Some((c, v)) => (c.name().to_string(), pct(v as f64 / lg.total_cycles.max(1) as f64)),
            None => ("-".into(), "-".into()),
        };
        let mut row = vec![r.name.clone()];
        for c in Cat::ALL {
            row.push(if r.get(c) == 0 { "-".into() } else { cycles(r.get(c)) });
        }
        row.push(cause);
        row.push(share);
        rows.push(row);
    }
    let mut header: Vec<&str> = vec!["row"];
    header.extend(snax::sim::CAT_NAMES);
    header.push("bottleneck");
    header.push("share");
    table(&header, &rows)
}

/// Roofline placement of one profiled run, derived from the retired-ops
/// checksum counters and AXI traffic (reuses [`snax::metrics::roofline`]).
fn roofline_json(cfg: &ClusterConfig, report: &snax::sim::SimReport) -> snax::runtime::json::Value {
    use snax::metrics::roofline;
    use snax::runtime::json::Value;
    let c = &report.counters;
    let ops = (2 * c.macs_retired + c.elem_ops_retired) as f64;
    let bytes = (c.axi_beats as f64) * roofline::axi_bytes_per_cycle(cfg);
    let intensity = if bytes > 0.0 { ops / bytes } else { 0.0 };
    let achieved = ops / report.total_cycles.max(1) as f64;
    let bound = roofline::roofline_bound(cfg, intensity);
    Value::object([
        ("intensity_ops_per_byte", Value::from(intensity)),
        ("achieved_ops_per_cycle", Value::from(achieved)),
        ("bound_ops_per_cycle", Value::from(bound)),
        ("peak_ops_per_cycle", Value::from(roofline::peak_ops_per_cycle(cfg))),
        ("utilization", Value::from(if bound > 0.0 { achieved / bound } else { 0.0 })),
    ])
}

/// Print the bottleneck report of one profiled cluster run and return
/// its JSON fragment: ledger rollup + per-layer spans + roofline
/// placement.
fn profile_cluster_fragment(
    cfg: &ClusterConfig,
    report: &snax::sim::SimReport,
) -> Result<snax::runtime::json::Value> {
    use snax::runtime::json::Value;
    let lg = report.ledger.as_ref().expect("profiled run carries a ledger");
    if let Some(err) = lg.conservation_error() {
        bail!("cycle-accounting violation: {err}");
    }
    println!("{}", ledger_table(lg));
    let rf = roofline_json(cfg, report);
    println!(
        "roofline: {:.1} ops/cyc achieved of {:.1} bound ({} at {:.2} ops/B)",
        rf.get("achieved_ops_per_cycle").unwrap().as_f64().unwrap(),
        rf.get("bound_ops_per_cycle").unwrap().as_f64().unwrap(),
        pct(rf.get("utilization").unwrap().as_f64().unwrap()),
        rf.get("intensity_ops_per_byte").unwrap().as_f64().unwrap(),
    );
    let layers: Vec<Value> = report
        .layers
        .iter()
        .map(|(id, l)| {
            Value::object([
                ("id", Value::from(*id as u64)),
                ("name", Value::from(l.name.as_str())),
                ("busy_cycles", Value::from(l.busy_cycles)),
                ("span_cycles", Value::from(l.span())),
                (
                    "span_share",
                    Value::from(l.span() as f64 / report.total_cycles.max(1) as f64),
                ),
            ])
        })
        .collect();
    Ok(Value::object([
        ("cluster", Value::from(cfg.name.as_str())),
        ("total_cycles", Value::from(report.total_cycles)),
        ("ledger", snax::server::ledger_json(lg)),
        ("layers", Value::Arr(layers)),
        ("roofline", rf),
    ]))
}

/// `snax profile`: run with the cycle-accounting ledger enabled and
/// print where every unit's cycles went (DESIGN.md §10).
fn cmd_profile(args: &Args) -> Result<()> {
    use snax::runtime::json::Value;
    let (opts, mode, memo, threads) = sim_options(args)?;
    let g = graph_for(&args.get("net", "fig6a"))?;
    let envelope = if args.has("system") || args.has("partition") {
        let sys = system_for(args)?;
        let strategy = match args.flags.get("partition") {
            Some(s) => PartitionStrategy::parse(s)?,
            None => PartitionStrategy::default_for(&sys),
        };
        let cs = compile_system(&g, &sys, &opts, strategy)?;
        let rep = System::new(&sys)
            .with_memo(memo)
            .with_threads(threads)
            .with_ledger(true)
            .run_mode(&cs.programs(), mode)?;
        println!(
            "profile: net={} system={} partition={} mode={:?} total {} cycles",
            cs.net,
            sys.name,
            cs.plan.strategy.name(),
            mode,
            cycles(rep.total_cycles)
        );
        let mut members = Vec::new();
        for (r, cfg) in rep.clusters.iter().zip(&sys.clusters) {
            println!("-- cluster {}", cfg.name);
            members.push(profile_cluster_fragment(cfg, r)?);
        }
        let noc_row = snax::sim::ledger::noc_row(rep.noc.busy_cycles, rep.total_cycles);
        if sys.n_clusters() > 1 {
            println!("-- shared noc");
            println!(
                "{}",
                ledger_table(&snax::sim::LedgerReport {
                    total_cycles: rep.total_cycles,
                    rows: vec![noc_row.clone()],
                })
            );
        }
        Value::object([
            ("net", Value::from(cs.net.as_str())),
            ("system", Value::from(sys.name.as_str())),
            ("partition", Value::from(cs.plan.strategy.name())),
            ("mode", Value::from(format!("{mode:?}").to_lowercase())),
            ("inferences", Value::from(cs.n_inferences())),
            ("total_cycles", Value::from(rep.total_cycles)),
            (
                "noc_ledger",
                snax::server::ledger_json(&snax::sim::LedgerReport {
                    total_cycles: rep.total_cycles,
                    rows: vec![noc_row],
                }),
            ),
            ("clusters", Value::Arr(members)),
        ])
    } else {
        let cfg = cluster_for(args)?;
        let cp = compile(&g, &cfg, &opts)?;
        let report = Cluster::new(&cfg)
            .with_memo(memo)
            .with_ledger(true)
            .run_mode(&cp.program, mode)?;
        println!(
            "profile: net={} cluster={} mode={:?} total {} cycles",
            g.name,
            cfg.name,
            mode,
            cycles(report.total_cycles)
        );
        let fragment = profile_cluster_fragment(&cfg, &report)?;
        Value::object([
            ("net", Value::from(g.name.as_str())),
            ("mode", Value::from(format!("{mode:?}").to_lowercase())),
            ("inferences", Value::from(opts.n_inferences)),
            ("total_cycles", Value::from(report.total_cycles)),
            ("clusters", Value::Arr(vec![fragment])),
        ])
    };
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, envelope.to_json())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote profile json to {path}");
    }
    Ok(())
}

/// One row of sweep output (accumulated in job order).
struct SweepRow {
    net: String,
    cluster: String,
    cycles: u64,
    ms: f64,
    energy_uj: f64,
    json: String,
}

fn cmd_sweep(args: &Args) -> Result<()> {
    // `--nets a,b` (falls back to `--net`) x `--clusters x,y` (falls
    // back to `--cluster`; entries may be presets or .toml paths).
    let nets: Vec<String> = args
        .get("nets", &args.get("net", "fig6a"))
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let cluster_specs: Vec<String> = args
        .get("clusters", &args.get("cluster", "fig6b,fig6c,fig6d"))
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if nets.is_empty() || cluster_specs.is_empty() {
        bail!("sweep needs at least one net and one cluster");
    }
    let mut clusters = Vec::new();
    for spec in &cluster_specs {
        let cfg = if spec.ends_with(".toml") {
            ClusterConfig::from_path(std::path::Path::new(spec))?
        } else {
            ClusterConfig::preset(spec)?
        };
        clusters.push(cfg);
    }
    let (opts, mode, memo, threads_opt) = sim_options(args)?;
    let threads: usize = threads_opt.unwrap_or_else(snax::parallel::default_parallelism);
    // One phase cache for the whole batch: jobs sharing a (net,
    // cluster) control structure replay each other's barrier-to-barrier
    // phases. Replay is byte-equivalent to simulation, so results stay
    // deterministic at any worker count.
    let phase_cache = std::sync::Arc::new(snax::sim::PhaseCache::new(4096));

    // Cross product in input order; `map_indexed` keeps result slot i
    // bound to job i, so output order is deterministic at any thread
    // count.
    let jobs: Vec<(String, ClusterConfig)> = nets
        .iter()
        .flat_map(|net| clusters.iter().map(move |c| (net.clone(), c.clone())))
        .collect();
    let fan_out = threads.max(1).min(jobs.len().max(1));
    // Split the core budget between job-level fan-out and per-retire
    // band threads instead of multiplying them: with fan_out jobs in
    // flight each job's kernels get cores/fan_out workers (and with a
    // single job, full auto band parallelism).
    let kernel_cap = (snax::parallel::default_parallelism() / fan_out).max(1);
    let t0 = std::time::Instant::now();
    let results = snax::parallel::map_indexed(jobs.len(), fan_out, |i| {
        let (net, cfg) = &jobs[i];
        let run = || -> Result<SweepRow> {
            let g = graph_for(net)?;
            let cp = compile(&g, cfg, &opts)?;
            let mut cluster = Cluster::new(cfg)
                .with_memo(memo)
                .with_phase_cache(phase_cache.clone());
            if fan_out > 1 {
                cluster = cluster.with_func_threads(kernel_cap);
            }
            let report = cluster.run_mode(&cp.program, mode)?;
            let e = energy::energy(&report, cfg);
            Ok(SweepRow {
                net: net.clone(),
                cluster: cfg.name.clone(),
                cycles: report.total_cycles,
                ms: report.seconds(cfg.freq_mhz) * 1e3,
                energy_uj: e.total_uj(),
                json: snax::server::render_report(&cp, cfg, &report),
            })
        };
        run().with_context(|| format!("sweep job {i} ({net} on {})", cfg.name))
    });
    let wall = t0.elapsed().as_secs_f64();

    let mut rows = Vec::new();
    let mut errors = Vec::new();
    let mut json_results = Vec::new();
    for r in &results {
        match r {
            Ok(row) => {
                rows.push(vec![
                    row.net.clone(),
                    row.cluster.clone(),
                    cycles(row.cycles),
                    format!("{:.3}", row.ms),
                    format!("{:.2}", row.energy_uj),
                ]);
                json_results.push(row.json.clone());
            }
            Err(e) => {
                let msg = format!("{e:#}");
                json_results.push(
                    snax::runtime::json::Value::object([(
                        "error",
                        snax::runtime::json::Value::from(msg.as_str()),
                    )])
                    .to_json(),
                );
                errors.push(msg);
            }
        }
    }
    println!(
        "sweep: {} jobs ({} nets x {} clusters) on {} threads in {:.2}s",
        jobs.len(),
        nets.len(),
        clusters.len(),
        fan_out,
        wall
    );
    println!("{}", table(&["net", "cluster", "cycles", "ms", "energy uJ"], &rows));
    if memo && mode == snax::sim::SimMode::Event {
        let ps = phase_cache.stats();
        println!(
            "phase cache: {} hits / {} misses, {} cycles replayed, {} records",
            ps.hits, ps.misses, ps.replayed_cycles, ps.entries
        );
    }
    if let Some(path) = args.flags.get("json") {
        let body = snax::server::render_sweep_body(&json_results);
        std::fs::write(path, body).with_context(|| format!("writing {path}"))?;
        println!("wrote {} results to {path}", jobs.len());
    }
    if !errors.is_empty() {
        bail!("{} sweep job(s) failed:\n  {}", errors.len(), errors.join("\n  "));
    }
    Ok(())
}

fn cmd_roofline(args: &Args) -> Result<()> {
    let cfg = ClusterConfig::fig6c();
    let tiles: Vec<u64> = args
        .get("tiles", "16,24,32,48,64,96,128")
        .split(',')
        .map(|t| t.trim().parse().context("bad tile"))
        .collect::<Result<_>>()?;
    let baseline = args.has("baseline");
    let mut rows = Vec::new();
    for t in tiles {
        let w = MatmulWorkload::square(t, 8);
        let prog = if baseline {
            serialized_program(&cfg, w)?
        } else {
            overlapped_program(&cfg, w)?
        };
        let report = Cluster::new(&cfg).run(&prog)?;
        let p = RooflinePoint::from_run(&cfg, &w, &report);
        rows.push(vec![
            format!("{t}"),
            format!("{:.2}", p.intensity),
            format!("{:.1}", p.achieved),
            format!("{:.1}", p.bound),
            pct(p.utilization()),
        ]);
    }
    println!(
        "roofline ({}) — peak {:.0} ops/cyc, AXI {:.0} B/cyc",
        if baseline { "serialized baseline" } else { "SNAX overlapped" },
        snax::metrics::roofline::peak_ops_per_cycle(&cfg),
        snax::metrics::roofline::axi_bytes_per_cycle(&cfg),
    );
    println!(
        "{}",
        table(&["tile", "ops/B", "achieved ops/cyc", "bound", "util"], &rows)
    );
    Ok(())
}

fn cmd_report(_args: &Args) -> Result<()> {
    let mut rows = Vec::new();
    for preset in ["fig6b", "fig6c", "fig6d"] {
        let cfg = ClusterConfig::preset(preset)?;
        let a = energy::area(&cfg);
        let mut row = vec![preset.to_string()];
        for comp in
            ["control_cores", "spm", "tcdm_interconnect", "streamers", "accelerators", "dma_axi"]
        {
            row.push(format!("{:.4}", a.get(comp)));
        }
        row.push(format!("{:.4}", a.total()));
        rows.push(row);
    }
    println!(
        "{}",
        table(
            &["config", "cores", "spm", "tcdm", "streamers", "accels", "dma+axi", "total mm2"],
            &rows
        )
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = snax::config::ServerConfig::default();
    cfg.port = args.get("port", &cfg.port.to_string()).parse().context("bad --port")?;
    if args.has("workers") {
        cfg.workers = args.get("workers", "1").parse().context("bad --workers")?;
    }
    if args.has("cache") {
        cfg.cache_capacity = args.get("cache", "64").parse().context("bad --cache")?;
    }
    if args.has("queue") {
        cfg.queue_depth = args.get("queue", "1").parse().context("bad --queue")?;
    }
    if args.has("phase-cache") {
        cfg.phase_cache_capacity =
            args.get("phase-cache", "2048").parse().context("bad --phase-cache")?;
    }
    if args.has("deadline-ms") {
        cfg.default_deadline_ms =
            args.get("deadline-ms", "0").parse().context("bad --deadline-ms")?;
    }
    if args.has("breaker") {
        cfg.breaker = match args.get("breaker", "on").as_str() {
            "on" | "true" => true,
            "off" | "false" => false,
            other => bail!("bad --breaker '{other}' (want on|off)"),
        };
    }
    if args.has("quota-rps") {
        cfg.quota_rps = args.get("quota-rps", "0").parse().context("bad --quota-rps")?;
    }
    if let Some(path) = args.flags.get("journal") {
        cfg.journal_path = Some(path.clone());
    }
    if args.has("job-ttl-ms") {
        cfg.job_ttl_ms =
            args.get("job-ttl-ms", "0").parse().context("bad --job-ttl-ms")?;
    }
    if args.has("max-jobs") {
        cfg.max_jobs = args.get("max-jobs", "1024").parse().context("bad --max-jobs")?;
    }
    if let Some(spec) = args.flags.get("fault") {
        cfg.fault_spec = Some(spec.clone());
    }
    if args.has("journal-max-bytes") {
        cfg.journal_max_bytes = args
            .get("journal-max-bytes", "0")
            .parse()
            .context("bad --journal-max-bytes")?;
    }
    if let Some(peers) = args.flags.get("peers") {
        cfg.peers = peers
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(str::to_string)
            .collect();
    }
    if let Some(id) = args.flags.get("node-id") {
        cfg.node_id = Some(id.clone());
    }
    snax::server::run_blocking(cfg)
}

fn cmd_verify(args: &Args) -> Result<()> {
    let net = args.get("net", "fig6a");
    let g = graph_for(&net)?;
    let cfg = cluster_for(args)?;
    // 1. Golden functional evaluation.
    let golden = models::evaluate(&g)?;
    // 2. Cycle-accurate simulation.
    let cp = compile(&g, &cfg, &CompileOptions::sequential())?;
    let report = Cluster::new(&cfg).run(&cp.program)?;
    let sim_out = cp.read_output(&report, 0, 0);
    if sim_out != golden[0] {
        bail!("simulator output != golden evaluator for '{net}'");
    }
    println!("sim == golden: OK ({} bytes)", sim_out.len());
    // 3. PJRT artifact.
    if !snax::runtime::PJRT_ENABLED {
        println!("PJRT artifact check skipped (built without the `pjrt` feature)");
        return Ok(());
    }
    let store = ArtifactStore::open_default()?;
    let meta = store
        .meta(&net)
        .with_context(|| format!("artifact '{net}' missing — run `make artifacts`"))?
        .clone();
    let in_shape = meta.inputs[0].0.clone();
    let n_in: usize = in_shape.iter().product();
    // One shared seed mapping (models::specs) — the same one the graph
    // builders bake into their input tensors.
    let seed = models::input_seed_by_name(&net)?;
    let x = Tensor::from_i8(&in_shape, &snax::models::lcg::lcg_i8(seed, n_in));
    let outs = store.execute(&net, &[x])?;
    // The artifact returns the first valid row; the graph output is the
    // 8-row GeMM-padded tensor (all rows identical for tiled nets).
    let artifact_bytes = &outs[0].data;
    let n_cmp = artifact_bytes.len().min(sim_out.len());
    if sim_out[..n_cmp] != artifact_bytes[..n_cmp] {
        bail!("PJRT artifact output != simulator output for '{net}'");
    }
    println!("sim == PJRT artifact: OK ({n_cmp} bytes)");
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let cfg = ClusterConfig::preset(&args.get("preset", "fig6d"))?;
    print!("{}", cfg.to_toml());
    Ok(())
}

fn cmd_fig8(args: &Args) -> Result<()> {
    use snax::runtime::json::Value;
    let g = models::fig6a_graph();
    let seq = CompileOptions::sequential();
    let mut rows = Vec::new();
    let mut json_rows: Vec<Value> = Vec::new();
    let mut prev: Option<u64> = None;
    for preset in ["fig6b", "fig6c", "fig6d"] {
        let cfg = ClusterConfig::preset(preset)?;
        let cp = compile(&g, &cfg, &seq)?;
        let r = Cluster::new(&cfg).run(&cp.program)?;
        let speedup = prev.map(|p| p as f64 / r.total_cycles as f64);
        rows.push(vec![
            preset.into(),
            cycles(r.total_cycles),
            speedup.map(ratio).unwrap_or_else(|| "-".into()),
        ]);
        json_rows.push(Value::object([
            ("platform", Value::from(preset)),
            ("cycles", Value::from(r.total_cycles)),
            ("per_inference", Value::from(false)),
            ("step_speedup", speedup.map(Value::from).unwrap_or(Value::Null)),
        ]));
        prev = Some(r.total_cycles);
    }
    // Pipelined on fig6d.
    let cfg = ClusterConfig::fig6d();
    let n = 8;
    let cp = compile(&g, &cfg, &CompileOptions::pipelined().with_inferences(n))?;
    let r = Cluster::new(&cfg).run(&cp.program)?;
    let per_inf = r.total_cycles / n as u64;
    let pipe_speedup = prev.unwrap() as f64 / per_inf as f64;
    rows.push(vec![
        "fig6d pipelined".into(),
        format!("{} /inf", cycles(per_inf)),
        ratio(pipe_speedup),
    ]);
    json_rows.push(Value::object([
        ("platform", Value::from("fig6d pipelined")),
        ("cycles", Value::from(per_inf)),
        ("per_inference", Value::from(true)),
        ("step_speedup", Value::from(pipe_speedup)),
    ]));
    println!("{}", table(&["platform", "cycles", "step speedup"], &rows));
    if let Some(path) = args.flags.get("json") {
        // Same envelope shape as the simulate/sweep surfaces
        // ({"count": N, "results": [...]}), so CI consumes the
        // heterogeneous cascade like any other machine-readable run.
        let body = Value::object([
            ("count", Value::from(json_rows.len())),
            ("results", Value::Arr(json_rows)),
        ])
        .to_json();
        std::fs::write(path, body).with_context(|| format!("writing {path}"))?;
        println!("wrote fig8 json to {path}");
    }
    Ok(())
}

fn help() {
    println!(
        "snax — SNAX multi-accelerator cluster reproduction\n\n\
         commands:\n\
         \u{20}  simulate --net fig6a|dae|resnet8 --cluster fig6b|fig6c|fig6d|file.toml\n\
         \u{20}           [--pipelined] [--inferences N] [--trace out.json]\n\
         \u{20}           [--engine event|exact] [--memo on|off] [--json out.json]\n\
         \u{20}           (--memo: barrier-delimited phase replay; identical reports,\n\
         \u{20}            --json includes phase-cache hit/miss counters)\n\
         \u{20}           [--system soc2|soc4|soc8|soc16|preset|file.toml]\n\
         \u{20}           [--partition none|pipeline|data] [--threads N]\n\
         \u{20}           (--threads: driver fan-out for independent members; reports\n\
         \u{20}            are byte-identical at any thread count, see DESIGN.md §14)\n\
         \u{20}           (multi-cluster SoC: cross-cluster partition pass, shared-NoC\n\
         \u{20}            contention, per-cluster reports; single presets = system-of-1)\n\
         \u{20}           [--checkpoint-dir dir] [--checkpoint-every N] [--resume file|dir]\n\
         \u{20}           (barrier-boundary checkpoints; a resumed run's report is\n\
         \u{20}            byte-identical to an uninterrupted one; see DESIGN.md §12)\n\
         \u{20}  sweep     --nets fig6a,dae --clusters fig6b,fig6c,fig6d\n\
         \u{20}            [--pipelined] [--inferences N] [--engine event|exact]\n\
         \u{20}            [--memo on|off] [--threads N] [--json out.json]\n\
         \u{20}            (parallel net x cluster fan-out, deterministic order,\n\
         \u{20}             shared phase cache across the batch)\n\
         \u{20}  serve     [--port 8080] [--workers N] [--cache entries] [--queue depth]\n\
         \u{20}            [--phase-cache slots] (0 disables phase memoization)\n\
         \u{20}            [--deadline-ms D] (default per-request wall deadline, 0=off)\n\
         \u{20}            [--breaker on|off] [--quota-rps R] (admission control)\n\
         \u{20}            [--journal path] (crash-safe job journal: jobs survive\n\
         \u{20}             restarts, interrupted ones auto-resume from checkpoints)\n\
         \u{20}            [--job-ttl-ms T] [--max-jobs N] (finished-job retention)\n\
         \u{20}            [--journal-max-bytes B] (compact the journal past this size)\n\
         \u{20}            [--fault spec] (chaos injection, e.g. crash:1.0,first:1;\n\
         \u{20}             peer_drop:p / peer_slow:p,peer_slow_ms:n partition peers)\n\
         \u{20}            [--peers host:port,...] [--node-id host:port] (fleet mode:\n\
         \u{20}             consistent-hash shared caches with peer health and\n\
         \u{20}             local-only degradation; see DESIGN.md §13)\n\
         \u{20}            (concurrent compile+simulate HTTP service; see DESIGN.md §6, §11)\n\
         \u{20}  profile   --net fig6a --cluster fig6d [--system soc2|soc4|soc8|soc16]\n\
         \u{20}            [--pipelined] [--inferences N] [--engine event|exact]\n\
         \u{20}            [--memo on|off] [--threads N] [--json out.json]\n\
         \u{20}            (cycle-accounting ledger: per-unit stall-cause attribution,\n\
         \u{20}             roofline placement, per-layer spans; see DESIGN.md §10)\n\
         \u{20}  fig8      [--json out.json] (the heterogeneous-acceleration cascade)\n\
         \u{20}  roofline  [--tiles 16,32,64] [--baseline]\n\
         \u{20}  report    (area breakdown per preset)\n\
         \u{20}  verify    --net fig6a (sim vs golden vs PJRT artifact)\n\
         \u{20}  config    --preset fig6d (dump TOML)"
    );
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "profile" => cmd_profile(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "roofline" => cmd_roofline(&args),
        "report" => cmd_report(&args),
        "verify" => cmd_verify(&args),
        "config" => cmd_config(&args),
        "fig8" => cmd_fig8(&args),
        "help" | "--help" | "-h" => {
            help();
            Ok(())
        }
        other => {
            help();
            bail!("unknown command '{other}'")
        }
    }
}
