//! Tensor-workload IR — the compiler's input, playing the role of the
//! MLIR func/linalg level in SNAX-MLIR.
//!
//! A [`Graph`] is a DAG of quantized-int8 tensor ops over named tensors.
//! Builders perform shape inference and validity checks, so every graph
//! reaching the passes is well-formed.

use anyhow::{bail, ensure, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I8,
    I32,
}

impl DType {
    pub fn bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 => 4,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// Where a tensor's bytes come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// Network input, materialized from the shared deterministic LCG.
    Input { seed: u64 },
    /// Layer weights, materialized from the LCG (bit-exact with the JAX
    /// side, see `python/compile/model.py`).
    Weight { seed: u64 },
    /// Produced by a node.
    Intermediate,
    /// Produced by a node and DMA'd back to external memory at the end.
    Output,
}

#[derive(Debug, Clone)]
pub struct TensorDesc {
    pub name: String,
    /// Row-major dims; activations NHWC, matmul operands [M,K]/[K,N].
    pub dims: Vec<u32>,
    pub dtype: DType,
    pub kind: TensorKind,
}

impl TensorDesc {
    pub fn elems(&self) -> u64 {
        self.dims.iter().map(|&d| d as u64).product()
    }

    pub fn bytes(&self) -> u64 {
        self.elems() * self.dtype.bytes() as u64
    }
}

/// Operation kinds. `shift` is the requantization shift; ops with
/// `logits` (or no requant) produce int32.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// NHWC conv; inputs: [activation, weight]. Weight stored
    /// `[kh*kw*cin, cout]` (im2col layout).
    Conv2d { kh: u32, kw: u32, stride: u32, pad: u32, relu: bool, shift: u32 },
    /// NHWC max-pool, kernel `k` stride `s`.
    MaxPool2d { k: u32, s: u32 },
    /// `[M,K] x [K,N]`; inputs: [activation, weight]. `logits` keeps
    /// int32 output (no requant).
    Dense { relu: bool, shift: u32, logits: bool },
    /// NHWC -> [N, C] int8.
    GlobalAvgPool,
    /// Saturating int8 add of two equal-shape tensors.
    ResidualAdd { relu: bool },
    /// Replicate a [1, len] row into [rows, len] (GeMM M-tile padding).
    TileRows { rows: u32 },
}

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub kind: OpKind,
    /// Activation inputs first, then weights.
    pub inputs: Vec<TensorId>,
    pub output: TensorId,
}

/// A complete workload graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<TensorDesc>,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Self { name: name.into(), ..Default::default() }
    }

    pub fn tensor(&self, id: TensorId) -> &TensorDesc {
        &self.tensors[id.0]
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    fn add_tensor(&mut self, desc: TensorDesc) -> TensorId {
        self.tensors.push(desc);
        TensorId(self.tensors.len() - 1)
    }

    pub fn add_input(&mut self, name: &str, dims: &[u32], seed: u64) -> TensorId {
        self.add_tensor(TensorDesc {
            name: name.into(),
            dims: dims.to_vec(),
            dtype: DType::I8,
            kind: TensorKind::Input { seed },
        })
    }

    fn add_weight(&mut self, name: &str, dims: &[u32], seed: u64) -> TensorId {
        self.add_tensor(TensorDesc {
            name: name.into(),
            dims: dims.to_vec(),
            dtype: DType::I8,
            kind: TensorKind::Weight { seed },
        })
    }

    fn add_node(
        &mut self,
        name: &str,
        kind: OpKind,
        inputs: Vec<TensorId>,
        out_dims: Vec<u32>,
        out_dtype: DType,
    ) -> (NodeId, TensorId) {
        let out = self.add_tensor(TensorDesc {
            name: format!("{name}.out"),
            dims: out_dims,
            dtype: out_dtype,
            kind: TensorKind::Intermediate,
        });
        self.nodes.push(Node { name: name.into(), kind, inputs, output: out });
        (NodeId(self.nodes.len() - 1), out)
    }

    /// NHWC conv + fused requant/relu. Returns the output tensor.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        &mut self,
        name: &str,
        x: TensorId,
        cout: u32,
        kh: u32,
        kw: u32,
        stride: u32,
        pad: u32,
        relu: bool,
        shift: u32,
        w_seed: u64,
    ) -> Result<TensorId> {
        let xd = self.tensor(x);
        ensure!(xd.dims.len() == 4, "{name}: conv input must be NHWC");
        ensure!(xd.dtype == DType::I8, "{name}: conv input must be int8");
        let (n, h, w, cin) = (xd.dims[0], xd.dims[1], xd.dims[2], xd.dims[3]);
        ensure!(h + 2 * pad >= kh && w + 2 * pad >= kw, "{name}: kernel exceeds input");
        let ho = (h + 2 * pad - kh) / stride + 1;
        let wo = (w + 2 * pad - kw) / stride + 1;
        let wt = self.add_weight(&format!("{name}.w"), &[kh * kw * cin, cout], w_seed);
        let (_, out) = self.add_node(
            name,
            OpKind::Conv2d { kh, kw, stride, pad, relu, shift },
            vec![x, wt],
            vec![n, ho, wo, cout],
            DType::I8,
        );
        Ok(out)
    }

    pub fn maxpool2d(&mut self, name: &str, x: TensorId, k: u32, s: u32) -> Result<TensorId> {
        let xd = self.tensor(x);
        ensure!(xd.dims.len() == 4, "{name}: pool input must be NHWC");
        let (n, h, w, c) = (xd.dims[0], xd.dims[1], xd.dims[2], xd.dims[3]);
        ensure!(h >= k && w >= k, "{name}: pool kernel exceeds input");
        let ho = (h - k) / s + 1;
        let wo = (w - k) / s + 1;
        let (_, out) = self.add_node(
            name,
            OpKind::MaxPool2d { k, s },
            vec![x],
            vec![n, ho, wo, c],
            DType::I8,
        );
        Ok(out)
    }

    /// Dense layer over `[M, K]` input (input is viewed as 2-D by
    /// flattening trailing dims).
    pub fn dense(
        &mut self,
        name: &str,
        x: TensorId,
        n_out: u32,
        relu: bool,
        shift: u32,
        logits: bool,
        w_seed: u64,
    ) -> Result<TensorId> {
        let xd = self.tensor(x);
        let m = xd.dims[0];
        let k: u32 = xd.dims[1..].iter().product();
        ensure!(k > 0, "{name}: empty dense input");
        let wt = self.add_weight(&format!("{name}.w"), &[k, n_out], w_seed);
        let (_, out) = self.add_node(
            name,
            OpKind::Dense { relu, shift, logits },
            vec![x, wt],
            vec![m, n_out],
            if logits { DType::I32 } else { DType::I8 },
        );
        Ok(out)
    }

    pub fn global_avgpool(&mut self, name: &str, x: TensorId) -> Result<TensorId> {
        let xd = self.tensor(x);
        ensure!(xd.dims.len() == 4, "{name}: avgpool input must be NHWC");
        let (n, c) = (xd.dims[0], xd.dims[3]);
        let (_, out) =
            self.add_node(name, OpKind::GlobalAvgPool, vec![x], vec![n, c], DType::I8);
        Ok(out)
    }

    pub fn residual_add(
        &mut self,
        name: &str,
        a: TensorId,
        b: TensorId,
        relu: bool,
    ) -> Result<TensorId> {
        let (ad, bd) = (self.tensor(a), self.tensor(b));
        ensure!(ad.dims == bd.dims, "{name}: shape mismatch {:?} vs {:?}", ad.dims, bd.dims);
        let dims = ad.dims.clone();
        let (_, out) =
            self.add_node(name, OpKind::ResidualAdd { relu }, vec![a, b], dims, DType::I8);
        Ok(out)
    }

    pub fn tile_rows(&mut self, name: &str, x: TensorId, rows: u32) -> Result<TensorId> {
        let xd = self.tensor(x);
        let len: u32 = xd.dims.iter().product();
        let (_, out) = self.add_node(
            name,
            OpKind::TileRows { rows },
            vec![x],
            vec![rows, len],
            DType::I8,
        );
        Ok(out)
    }

    /// Mark a tensor as a network output (DMA'd to external memory).
    pub fn mark_output(&mut self, t: TensorId) {
        self.tensors[t.0].kind = TensorKind::Output;
    }

    /// Network inputs in declaration order.
    pub fn inputs(&self) -> Vec<TensorId> {
        self.tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t.kind, TensorKind::Input { .. }))
            .map(|(i, _)| TensorId(i))
            .collect()
    }

    pub fn outputs(&self) -> Vec<TensorId> {
        self.tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t.kind, TensorKind::Output))
            .map(|(i, _)| TensorId(i))
            .collect()
    }

    /// The node producing tensor `t`, if any.
    pub fn producer(&self, t: TensorId) -> Option<NodeId> {
        self.nodes.iter().position(|n| n.output == t).map(NodeId)
    }

    /// Total MACs across the graph (roofline / reporting).
    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| self.node_macs(n)).sum()
    }

    fn node_macs(&self, n: &Node) -> u64 {
        match n.kind {
            OpKind::Conv2d { kh, kw, .. } => {
                let od = self.tensor(n.output);
                let wd = self.tensor(n.inputs[1]);
                let cin = wd.dims[0] / (kh * kw);
                od.elems() * (kh * kw * cin) as u64
            }
            OpKind::Dense { .. } => {
                let od = self.tensor(n.output);
                let wd = self.tensor(n.inputs[1]);
                od.elems() * wd.dims[0] as u64
            }
            _ => 0,
        }
    }

    /// Structural sanity: every node's inputs exist and were produced
    /// before use (nodes are stored in topological order by builders).
    pub fn validate(&self) -> Result<()> {
        for (i, n) in self.nodes.iter().enumerate() {
            for &inp in &n.inputs {
                if inp.0 >= self.tensors.len() {
                    bail!("node '{}' references missing tensor", n.name);
                }
                if let Some(p) = self.producer(inp) {
                    if p.0 >= i {
                        bail!("node '{}' uses tensor produced later", n.name);
                    }
                }
            }
            let od = self.tensor(n.output);
            if od.elems() == 0 {
                bail!("node '{}' has empty output", n.name);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g.add_input("x", &[1, 8, 8, 8], 1);
        let c = g.conv2d("conv", x, 8, 3, 3, 1, 1, true, 8, 2).unwrap();
        let p = g.maxpool2d("pool", c, 2, 2).unwrap();
        let d = g.dense("fc", p, 8, false, 0, true, 3).unwrap();
        g.mark_output(d);
        g
    }

    #[test]
    fn shape_inference() {
        let g = tiny_graph();
        assert_eq!(g.tensor(g.nodes[0].output).dims, vec![1, 8, 8, 8]);
        assert_eq!(g.tensor(g.nodes[1].output).dims, vec![1, 4, 4, 8]);
        assert_eq!(g.tensor(g.nodes[2].output).dims, vec![1, 8]);
        assert_eq!(g.tensor(g.nodes[2].output).dtype, DType::I32);
        g.validate().unwrap();
    }

    #[test]
    fn dense_flattens_trailing_dims() {
        let g = tiny_graph();
        // fc weight: [4*4*8, 8]
        let w = g.tensor(g.nodes[2].inputs[1]);
        assert_eq!(w.dims, vec![128, 8]);
    }

    #[test]
    fn macs_accounting() {
        let g = tiny_graph();
        // conv: 64 out px * 8 cout * 72 K + fc: 8 * 128
        assert_eq!(g.total_macs(), 64 * 8 * 72 + 8 * 128);
    }

    #[test]
    fn io_queries() {
        let g = tiny_graph();
        assert_eq!(g.inputs().len(), 1);
        assert_eq!(g.outputs().len(), 1);
        assert!(g.producer(g.inputs()[0]).is_none());
        assert_eq!(g.producer(g.outputs()[0]), Some(NodeId(2)));
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut g = Graph::new("bad");
        let x = g.add_input("x", &[1, 2, 2, 8], 1);
        assert!(g.maxpool2d("pool", x, 4, 4).is_err());
        assert!(g.conv2d("c", x, 8, 5, 5, 1, 1, true, 8, 2).is_err());
    }
}
