//! Stable content fingerprints over compilation inputs.
//!
//! The `snax serve` program cache ([`crate::server::cache`]) is
//! content-addressed: two requests that compile the same `(workload
//! graph, cluster config, compile options)` triple must map to the same
//! key, across threads and across identical processes. `DefaultHasher`
//! gives no such guarantee, so this module hand-rolls 64-bit FNV-1a and
//! feeds it every semantically relevant field in a fixed order
//! (length-prefixed strings and sequences, one tag byte per enum
//! variant) — a change to any field that can alter compiler output
//! changes the key.

use crate::config::{AccelKind, ClusterConfig, SystemConfig};

use super::codegen::Mode;
use super::ir::{DType, Graph, OpKind, TensorKind};
use super::partition::PartitionStrategy;
use super::CompileOptions;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental 64-bit FNV-1a hasher.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    pub const fn new() -> Self {
        Fnv1a(FNV_OFFSET)
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u8(&mut self, v: u8) {
        self.write_bytes(&[v]);
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write_u8(v as u8);
    }

    /// Length-prefixed so `("ab", "c")` and `("a", "bc")` differ.
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write_bytes(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

fn feed_dtype(h: &mut Fnv1a, d: DType) {
    h.write_u8(match d {
        DType::I8 => 0,
        DType::I32 => 1,
    });
}

fn feed_graph(h: &mut Fnv1a, g: &Graph) {
    // Names matter: they flow into `Program::layer_names` and therefore
    // into every report a cached program produces.
    h.write_str(&g.name);
    h.write_u64(g.tensors.len() as u64);
    for t in &g.tensors {
        h.write_str(&t.name);
        h.write_u64(t.dims.len() as u64);
        for &d in &t.dims {
            h.write_u32(d);
        }
        feed_dtype(h, t.dtype);
        match t.kind {
            TensorKind::Input { seed } => {
                h.write_u8(0);
                h.write_u64(seed);
            }
            TensorKind::Weight { seed } => {
                h.write_u8(1);
                h.write_u64(seed);
            }
            TensorKind::Intermediate => h.write_u8(2),
            TensorKind::Output => h.write_u8(3),
        }
    }
    h.write_u64(g.nodes.len() as u64);
    for n in &g.nodes {
        h.write_str(&n.name);
        match n.kind {
            OpKind::Conv2d { kh, kw, stride, pad, relu, shift } => {
                h.write_u8(0);
                h.write_u32(kh);
                h.write_u32(kw);
                h.write_u32(stride);
                h.write_u32(pad);
                h.write_bool(relu);
                h.write_u32(shift);
            }
            OpKind::MaxPool2d { k, s } => {
                h.write_u8(1);
                h.write_u32(k);
                h.write_u32(s);
            }
            OpKind::Dense { relu, shift, logits } => {
                h.write_u8(2);
                h.write_bool(relu);
                h.write_u32(shift);
                h.write_bool(logits);
            }
            OpKind::GlobalAvgPool => h.write_u8(3),
            OpKind::ResidualAdd { relu } => {
                h.write_u8(4);
                h.write_bool(relu);
            }
            OpKind::TileRows { rows } => {
                h.write_u8(5);
                h.write_u32(rows);
            }
        }
        h.write_u64(n.inputs.len() as u64);
        for t in &n.inputs {
            h.write_u64(t.0 as u64);
        }
        h.write_u64(n.output.0 as u64);
    }
}

fn feed_config(h: &mut Fnv1a, c: &ClusterConfig) {
    h.write_str(&c.name);
    h.write_u32(c.spm_kb);
    h.write_u32(c.banks);
    h.write_u32(c.bank_width_bits);
    h.write_u32(c.axi_bits);
    h.write_u32(c.dma_bits);
    h.write_u8(c.dma_core);
    h.write_u32(c.freq_mhz);
    h.write_bool(c.csr_double_buffer);
    h.write_u64(c.cores.len() as u64);
    for core in &c.cores {
        h.write_u8(core.id);
        h.write_u32(core.imem_kb);
    }
    h.write_u64(c.accelerators.len() as u64);
    for a in &c.accelerators {
        h.write_str(&a.name);
        h.write_u8(match a.kind {
            AccelKind::Gemm => 0,
            AccelKind::MaxPool => 1,
            AccelKind::VecAdd => 2,
        });
        h.write_u8(a.core);
        h.write_u64(a.read_ports_bits.len() as u64);
        for &b in &a.read_ports_bits {
            h.write_u32(b);
        }
        h.write_u64(a.write_ports_bits.len() as u64);
        for &b in &a.write_ports_bits {
            h.write_u32(b);
        }
        h.write_u32(a.fifo_depth);
        h.write_u32(a.agu_loop_depth);
    }
}

fn feed_options(h: &mut Fnv1a, o: &CompileOptions) {
    h.write_u8(match o.mode {
        Mode::Sequential => 0,
        Mode::Pipelined => 1,
    });
    h.write_u32(o.n_inferences);
    h.write_u64(o.max_weight_slots as u64);
    h.write_u64(o.overrides.force_cpu.len() as u64);
    for name in &o.overrides.force_cpu {
        h.write_str(name);
    }
}

/// Content-addressed cache key for one compilation: stable across
/// clones, threads, and identical processes. The leading version tag
/// deliberately invalidates every cached program when the fingerprint
/// schema itself changes.
pub fn program_key(g: &Graph, cfg: &ClusterConfig, opts: &CompileOptions) -> u64 {
    let mut h = Fnv1a::new();
    h.write_str("snax-program-v1");
    feed_graph(&mut h, g);
    feed_config(&mut h, cfg);
    feed_options(&mut h, opts);
    h.finish()
}

/// Content-addressed cache key for one **system** compilation: the
/// graph, every member cluster (order matters — it is the partition
/// order), the shared-NoC description, the partition strategy, and the
/// compile options. Same guarantees as [`program_key`].
pub fn system_key(
    g: &Graph,
    sys: &SystemConfig,
    opts: &CompileOptions,
    strategy: PartitionStrategy,
) -> u64 {
    let mut h = Fnv1a::new();
    h.write_str("snax-system-v1");
    feed_graph(&mut h, g);
    h.write_str(&sys.name);
    h.write_u64(sys.clusters.len() as u64);
    for c in &sys.clusters {
        feed_config(&mut h, c);
    }
    h.write_u32(sys.noc.link_bits);
    h.write_u32(sys.noc.grants_per_cycle);
    h.write_u8(match strategy {
        PartitionStrategy::None => 0,
        PartitionStrategy::Pipeline => 1,
        PartitionStrategy::DataParallel => 2,
    });
    feed_options(&mut h, opts);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn fnv1a_known_vectors() {
        // Reference values for the standard 64-bit FNV-1a parameters.
        assert_eq!(Fnv1a::new().finish(), 0xcbf29ce484222325);
        let mut h = Fnv1a::new();
        h.write_bytes(b"a");
        assert_eq!(h.finish(), 0xaf63dc4c8601ec8c);
        let mut h = Fnv1a::new();
        h.write_bytes(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn key_is_stable_across_clones() {
        let g = models::fig6a_graph();
        let cfg = ClusterConfig::fig6d();
        let opts = CompileOptions::pipelined();
        let k1 = program_key(&g, &cfg, &opts);
        let k2 = program_key(&g.clone(), &cfg.clone(), &opts.clone());
        assert_eq!(k1, k2);
    }

    #[test]
    fn key_separates_graphs_configs_and_options() {
        let g = models::fig6a_graph();
        let cfg = ClusterConfig::fig6d();
        let opts = CompileOptions::sequential();
        let base = program_key(&g, &cfg, &opts);
        assert_ne!(base, program_key(&models::dae_graph(), &cfg, &opts));
        assert_ne!(base, program_key(&g, &ClusterConfig::fig6c(), &opts));
        assert_ne!(base, program_key(&g, &cfg, &CompileOptions::pipelined()));
        assert_ne!(
            base,
            program_key(&g, &cfg, &CompileOptions::sequential().with_inferences(2))
        );
        assert_ne!(
            base,
            program_key(&g, &cfg, &CompileOptions::sequential().single_weight_slot())
        );
        assert_ne!(
            base,
            program_key(&g, &cfg, &CompileOptions::sequential().force_cpu(&["conv1"]))
        );
    }

    #[test]
    fn key_sees_config_field_tweaks() {
        let g = models::fig6a_graph();
        let opts = CompileOptions::sequential();
        let cfg = ClusterConfig::fig6d();
        let base = program_key(&g, &cfg, &opts);
        let mut tweaked = cfg.clone();
        tweaked.banks = 64;
        assert_ne!(base, program_key(&g, &tweaked, &opts));
        let mut tweaked = cfg.clone();
        tweaked.accelerators[0].fifo_depth = 8;
        assert_ne!(base, program_key(&g, &tweaked, &opts));
    }

    #[test]
    fn system_key_separates_topologies_and_strategies() {
        let g = models::fig6a_graph();
        let opts = CompileOptions::sequential();
        let sys = SystemConfig::soc2();
        let base = system_key(&g, &sys, &opts, PartitionStrategy::Pipeline);
        assert_ne!(
            base,
            system_key(&g, &sys, &opts, PartitionStrategy::DataParallel),
            "strategy must separate keys"
        );
        assert_ne!(
            base,
            system_key(&g, &SystemConfig::soc4(), &opts, PartitionStrategy::Pipeline)
        );
        let mut tweaked = sys.clone();
        tweaked.noc.grants_per_cycle = 2;
        assert_ne!(base, system_key(&g, &tweaked, &opts, PartitionStrategy::Pipeline));
        let mut swapped = sys.clone();
        swapped.clusters.swap(0, 1);
        assert_ne!(
            base,
            system_key(&g, &swapped, &opts, PartitionStrategy::Pipeline),
            "cluster order is the partition order"
        );
        // Stable across clones.
        assert_eq!(
            base,
            system_key(&g.clone(), &sys.clone(), &opts.clone(), PartitionStrategy::Pipeline)
        );
    }

    #[test]
    fn length_prefixing_prevents_concatenation_collisions() {
        let mut a = Fnv1a::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = Fnv1a::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }
}
