//! Asynchronous scheduling + device programming (SNAX-MLIR passes 3/4,
//! paper Fig. 5.3–5.4).
//!
//! Translates a placed, allocated graph into per-core instruction
//! streams:
//!
//! * **Sequential mode** — layer by layer, barrier-separated, with
//!   weight-slot DMA prefetch overlapped when two slots exist.
//! * **Pipelined mode** — the paper's virtual pipeline, unrolled: stage
//!   `s` processes inference `t - s` in tick `t`; each core launches
//!   its accelerator jobs fire-and-forget, runs its software kernels
//!   while they execute, then awaits and barriers. Activations are
//!   double-buffered by the allocator so adjacent inferences never
//!   collide.
//!
//! Every accelerator interaction is emitted as explicit CSR writes
//! against the register maps in [`crate::isa`] — the compute kernel
//! (dims, shift, flags) and the dataflow kernel (streamer loop strides)
//! of the paper's hybrid-coupling split.

use anyhow::{bail, Result};

use crate::config::{AccelKind, ClusterConfig};
use crate::isa::{
    dma_csr, dma_dir, gemm_csr, maxpool_csr, vecadd_csr, BarrierId, Instr, LayerClass,
    Program, SwKernel, UnitId,
};
use crate::models::lcg::lcg_bytes;
use crate::sim::job::{OpDesc, Region};

use super::alloc::{AllocMap, WeightMode};
use super::cost::cpu_cycles;
use super::ir::{Graph, Node, NodeId, OpKind, TensorKind};
use super::placement::{Device, Placement};

/// Compilation mode (paper §VI-C: "the compiler determines whether to
/// enable pipelined execution or default to sequential execution based
/// on explicit configuration flags").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Sequential,
    Pipelined,
}

/// Cross-cluster synchronization contract of one pipeline-partitioned
/// part (emitted by [`crate::compiler::partition`]): before each
/// inference's input DMA the part waits on `wait_base + inf`, and after
/// each inference's output store it signals `signal_base + inf`. All
/// ids live in the [`crate::isa::SYS_BARRIER_BASE`] range and pair up
/// with the neighboring stage's matching fence (participants = 2).
#[derive(Debug, Clone, Copy)]
pub struct PartSync {
    pub wait_base: Option<u16>,
    pub signal_base: Option<u16>,
    pub participants: u8,
}

pub struct CodegenInput<'a> {
    pub graph: &'a Graph,
    pub cfg: &'a ClusterConfig,
    pub placement: &'a Placement,
    pub alloc: &'a AllocMap,
    pub mode: Mode,
    /// Inferences to run back-to-back (pipelined throughput needs > 1).
    pub n_inferences: u32,
    /// Cross-cluster handoff fences (None outside a partitioned
    /// system).
    pub sync: Option<PartSync>,
}

struct Ctx<'a> {
    g: &'a Graph,
    cfg: &'a ClusterConfig,
    place: &'a Placement,
    alloc: &'a AllocMap,
    streams: Vec<Vec<Instr>>,
    descs: Vec<OpDesc>,
    next_barrier: u16,
    part_sync: Option<PartSync>,
}

impl<'a> Ctx<'a> {
    fn core_idx(&self, core: crate::isa::CoreId) -> usize {
        self.cfg.core_index(core)
    }

    fn push(&mut self, core: usize, i: Instr) {
        self.streams[core].push(i);
    }

    fn sync(&mut self) {
        // Local barrier ids wrap below the system-barrier range
        // (ids >= SYS_BARRIER_BASE belong to cross-cluster fences).
        // Reuse is safe: every sync involves all cores, so syncs are
        // totally ordered and at most one id is ever in flight —
        // 0x8000 distinct ids are a vast re-use window.
        let id = BarrierId(self.next_barrier);
        self.next_barrier = (self.next_barrier + 1) % crate::isa::SYS_BARRIER_BASE;
        let participants = self.cfg.cores.len() as u8;
        if participants == 1 {
            return; // single core: program order is the barrier
        }
        for s in &mut self.streams {
            s.push(Instr::Barrier { id, participants });
        }
    }

    fn desc(&mut self, d: OpDesc) -> u64 {
        self.descs.push(d);
        (self.descs.len() - 1) as u64
    }

    fn layer_class(kind: &OpKind) -> LayerClass {
        match kind {
            OpKind::Conv2d { .. } => LayerClass::Conv,
            OpKind::MaxPool2d { .. } => LayerClass::MaxPool,
            OpKind::Dense { .. } => LayerClass::Dense,
            _ => LayerClass::Elementwise,
        }
    }

    // -- job emission helpers ------------------------------------------------

    /// Emit a 2-D DMA job on the DMA-controlling core. Does not await.
    #[allow(clippy::too_many_arguments)]
    fn emit_dma(
        &mut self,
        core: usize,
        src: u64,
        dst: u64,
        rows: u64,
        row_bytes: u64,
        src_stride: u64,
        dst_stride: u64,
        dir: u64,
    ) {
        let unit = self.cfg.dma_unit();
        let w = |reg, val| Instr::CsrWrite { unit, reg, val };
        self.push(core, w(dma_csr::SRC, src));
        self.push(core, w(dma_csr::DST, dst));
        self.push(core, w(dma_csr::ROW_BYTES, row_bytes));
        self.push(core, w(dma_csr::ROWS, rows));
        self.push(core, w(dma_csr::SRC_STRIDE, src_stride));
        self.push(core, w(dma_csr::DST_STRIDE, dst_stride));
        self.push(core, w(dma_csr::DIR, dir));
        self.push(core, Instr::Launch { unit });
    }

    /// GeMM-accelerator job for a dense/conv node. Does not await.
    #[allow(clippy::too_many_arguments)]
    fn emit_gemm_job(
        &mut self,
        core: usize,
        unit: UnitId,
        m: u64,
        k: u64,
        n: u64,
        a_addr: u64,
        b_addr: u64,
        c_addr: u64,
        a_row: u64,
        a_strides: [u64; 3],
        shift: u32,
        relu: bool,
        i32_out: bool,
        desc: u64,
    ) {
        let w = |reg, val| Instr::CsrWrite { unit, reg, val };
        let c_elt = if i32_out { 4u64 } else { 1 };
        self.push(core, w(gemm_csr::M, m));
        self.push(core, w(gemm_csr::K, k));
        self.push(core, w(gemm_csr::N, n));
        self.push(core, w(gemm_csr::PTR_A, a_addr));
        self.push(core, w(gemm_csr::PTR_B, b_addr));
        self.push(core, w(gemm_csr::PTR_C, c_addr));
        self.push(core, w(gemm_csr::ROW_A, a_row));
        self.push(core, w(gemm_csr::ROW_B, n));
        self.push(core, w(gemm_csr::ROW_C, c_elt * n));
        self.push(core, w(gemm_csr::STRIDE_A0, a_strides[0]));
        self.push(core, w(gemm_csr::STRIDE_A1, a_strides[1]));
        self.push(core, w(gemm_csr::STRIDE_A2, a_strides[2]));
        self.push(core, w(gemm_csr::STRIDE_B0, 8 * n));
        self.push(core, w(gemm_csr::STRIDE_B1, 8));
        self.push(core, w(gemm_csr::STRIDE_B2, 0));
        self.push(core, w(gemm_csr::STRIDE_C0, 8 * c_elt));
        self.push(core, w(gemm_csr::STRIDE_C1, 8 * c_elt * n));
        self.push(core, w(gemm_csr::SHIFT, shift as u64));
        let flags = u64::from(relu) | (u64::from(i32_out) << 1);
        self.push(core, w(gemm_csr::FLAGS, flags));
        self.push(core, w(gemm_csr::DESC, desc));
        self.push(core, Instr::Launch { unit });
    }

    /// Emit the launch (not await) of one graph node for pipeline
    /// iteration `iter`. Returns the executing core index.
    fn emit_node_launch(&mut self, ni: NodeId, iter: u64) -> Result<usize> {
        let node = &self.g.nodes[ni.0];
        let device = self.place.devices[ni.0];
        let class = Self::layer_class(&node.kind);
        match device {
            Device::Accel(unit) => {
                let core = self.core_idx(self.cfg.controlling_core(unit));
                self.push(core, Instr::SpanBegin { layer: ni.0 as u16, class });
                self.emit_accel_node(core, unit, node, ni, iter)?;
                Ok(core)
            }
            Device::Cpu(c) => {
                let core = self.core_idx(c);
                self.push(core, Instr::SpanBegin { layer: ni.0 as u16, class });
                let op = self.node_op_desc(node, ni, iter);
                let cycles = cpu_cycles(self.g, node);
                self.push(core, Instr::Sw { kernel: SwKernel { cycles, class, op: Some(op) } });
                self.push(core, Instr::SpanEnd { layer: ni.0 as u16 });
                Ok(core)
            }
        }
    }

    /// Await + span end for an accelerator node.
    fn emit_node_await(&mut self, ni: NodeId, core: usize, unit: UnitId) {
        self.push(core, Instr::AwaitIdle { unit });
        self.push(core, Instr::SpanEnd { layer: ni.0 as u16 });
    }

    fn weight_addr(&self, node: &Node, ni: NodeId) -> u64 {
        self.alloc.weight_spm(node.inputs[1], ni.0)
    }

    fn emit_accel_node(
        &mut self,
        core: usize,
        unit: UnitId,
        node: &Node,
        ni: NodeId,
        iter: u64,
    ) -> Result<()> {
        let a = self.alloc.spm(node.inputs[0], iter);
        let out = self.alloc.spm(node.output, iter);
        let kind = self.cfg.accelerators[unit.0 as usize].kind;
        match (kind, &node.kind) {
            (AccelKind::Gemm, OpKind::Dense { relu, shift, logits }) => {
                let wd = self.g.tensor(node.inputs[1]);
                let (k, n) = (wd.dims[0] as u64, wd.dims[1] as u64);
                let m = self.g.tensor(node.output).dims[0] as u64;
                if m % 8 != 0 || k % 8 != 0 || n % 8 != 0 {
                    bail!("dense '{}' dims {m}x{k}x{n} not 8-aligned", node.name);
                }
                let b = self.weight_addr(node, ni);
                let desc = self.desc(OpDesc::Gemm {
                    a: Region(a),
                    b: Region(b),
                    c: Region(out),
                    m: m as u32,
                    k: k as u32,
                    n: n as u32,
                    shift: if *logits { 0 } else { *shift },
                    relu: *relu,
                    i32_out: *logits,
                });
                self.emit_gemm_job(
                    core, unit, m, k, n, a, b, out,
                    k,                    // A row pitch
                    [8, 0, 8 * k],        // k-walk, reuse across n, next 8 rows
                    if *logits { 0 } else { *shift },
                    *relu,
                    *logits,
                    desc,
                );
                Ok(())
            }
            (AccelKind::Gemm, OpKind::Conv2d { kh, kw, stride, pad, relu, shift }) => {
                let xd = self.g.tensor(node.inputs[0]);
                let od = self.g.tensor(node.output);
                let (n_b, h, w_dim, cin) = (xd.dims[0], xd.dims[1], xd.dims[2], xd.dims[3]);
                let (ho, wo, cout) = (od.dims[1], od.dims[2], od.dims[3]);
                let m = (n_b * ho * wo) as u64;
                let k = (kh * kw * cin) as u64;
                let n = cout as u64;
                if m % 8 != 0 || k % 8 != 0 || n % 8 != 0 {
                    bail!("conv '{}' im2col dims {m}x{k}x{n} not 8-aligned", node.name);
                }
                let b = self.weight_addr(node, ni);
                let desc = self.desc(OpDesc::Conv2d {
                    input: Region(a),
                    weights: Region(b),
                    out: Region(out),
                    n: n_b,
                    h,
                    w: w_dim,
                    cin,
                    cout,
                    kh: *kh,
                    kw: *kw,
                    stride: *stride,
                    pad: *pad,
                    shift: *shift,
                    relu: *relu,
                });
                // im2col streamer approximation: adjacent patches start
                // stride*cin bytes apart; the k-walk advances through
                // the patch row.
                let patch_pitch = (*stride * cin) as u64;
                self.emit_gemm_job(
                    core, unit, m, k, n, a, b, out,
                    patch_pitch.max(8),
                    [8, 0, 8 * patch_pitch.max(8)],
                    *shift,
                    *relu,
                    false,
                    desc,
                );
                Ok(())
            }
            (AccelKind::MaxPool, OpKind::MaxPool2d { k, s }) => {
                let xd = self.g.tensor(node.inputs[0]);
                let (h, w_dim, c) = (xd.dims[1], xd.dims[2], xd.dims[3]);
                let desc = self.desc(OpDesc::MaxPool {
                    input: Region(a),
                    out: Region(out),
                    n: xd.dims[0],
                    h,
                    w: w_dim,
                    c,
                    k: *k,
                    s: *s,
                });
                let w = |reg, val| Instr::CsrWrite { unit, reg, val };
                self.push(core, w(maxpool_csr::H, h as u64));
                self.push(core, w(maxpool_csr::W, w_dim as u64));
                self.push(core, w(maxpool_csr::C, c as u64));
                self.push(core, w(maxpool_csr::KERNEL, *k as u64));
                self.push(core, w(maxpool_csr::STRIDE, *s as u64));
                self.push(core, w(maxpool_csr::PTR_IN, a));
                self.push(core, w(maxpool_csr::PTR_OUT, out));
                self.push(core, w(maxpool_csr::STRIDE_IN0, 64));
                self.push(core, w(maxpool_csr::STRIDE_IN1, 0));
                self.push(core, w(maxpool_csr::STRIDE_OUT0, 64));
                self.push(core, w(maxpool_csr::DESC, desc));
                self.push(core, Instr::Launch { unit });
                Ok(())
            }
            (AccelKind::VecAdd, OpKind::ResidualAdd { relu }) => {
                let b_in = self.alloc.spm(node.inputs[1], iter);
                let len = self.g.tensor(node.output).elems() as u64;
                let desc = self.desc(OpDesc::VecAdd {
                    a: Region(a),
                    b: Region(b_in),
                    out: Region(out),
                    len: len as u32,
                    relu: *relu,
                });
                let w = |reg, val| Instr::CsrWrite { unit, reg, val };
                self.push(core, w(vecadd_csr::LEN, len));
                self.push(core, w(vecadd_csr::PTR_A, a));
                self.push(core, w(vecadd_csr::PTR_B, b_in));
                self.push(core, w(vecadd_csr::PTR_OUT, out));
                self.push(core, w(vecadd_csr::DESC, desc));
                self.push(core, Instr::Launch { unit });
                Ok(())
            }
            (k, op) => bail!(
                "placement bug: node '{}' ({op:?}) mapped to {k:?} accelerator",
                node.name
            ),
        }
    }

    /// Functional descriptor for a CPU-executed node.
    fn node_op_desc(&mut self, node: &Node, ni: NodeId, iter: u64) -> OpDesc {
        let a = self.alloc.spm(node.inputs[0], iter);
        let out = self.alloc.spm(node.output, iter);
        match &node.kind {
            OpKind::Conv2d { kh, kw, stride, pad, relu, shift } => {
                let xd = self.g.tensor(node.inputs[0]);
                let od = self.g.tensor(node.output);
                OpDesc::Conv2d {
                    input: Region(a),
                    weights: Region(self.weight_addr(node, ni)),
                    out: Region(out),
                    n: xd.dims[0],
                    h: xd.dims[1],
                    w: xd.dims[2],
                    cin: xd.dims[3],
                    cout: od.dims[3],
                    kh: *kh,
                    kw: *kw,
                    stride: *stride,
                    pad: *pad,
                    shift: *shift,
                    relu: *relu,
                }
            }
            OpKind::Dense { relu, shift, logits } => {
                let wd = self.g.tensor(node.inputs[1]);
                OpDesc::Gemm {
                    a: Region(a),
                    b: Region(self.weight_addr(node, ni)),
                    c: Region(out),
                    m: self.g.tensor(node.output).dims[0],
                    k: wd.dims[0],
                    n: wd.dims[1],
                    shift: if *logits { 0 } else { *shift },
                    relu: *relu,
                    i32_out: *logits,
                }
            }
            OpKind::MaxPool2d { k, s } => {
                let xd = self.g.tensor(node.inputs[0]);
                OpDesc::MaxPool {
                    input: Region(a),
                    out: Region(out),
                    n: xd.dims[0],
                    h: xd.dims[1],
                    w: xd.dims[2],
                    c: xd.dims[3],
                    k: *k,
                    s: *s,
                }
            }
            OpKind::GlobalAvgPool => {
                let xd = self.g.tensor(node.inputs[0]);
                OpDesc::GlobalAvgPool {
                    input: Region(a),
                    out: Region(out),
                    n: xd.dims[0],
                    h: xd.dims[1],
                    w: xd.dims[2],
                    c: xd.dims[3],
                }
            }
            OpKind::ResidualAdd { relu } => OpDesc::VecAdd {
                a: Region(a),
                b: Region(self.alloc.spm(node.inputs[1], iter)),
                out: Region(out),
                len: self.g.tensor(node.output).elems() as u32,
                relu: *relu,
            },
            OpKind::TileRows { rows } => {
                let xd = self.g.tensor(node.inputs[0]);
                OpDesc::TileRows {
                    input: Region(a),
                    out: Region(out),
                    len: xd.elems() as u32,
                    rows: *rows,
                }
            }
        }
    }

    // -- data movement ---------------------------------------------------------

    /// DMA a network input from ext memory into its SPM buffer.
    /// `iter` selects the double buffer; `inf` is the inference index —
    /// pinned (handoff) inputs read the per-inference region the
    /// producing part wrote, seeded inputs re-read the one static image.
    fn emit_input_load(&mut self, iter: u64, inf: u64) -> usize {
        let dma_core = self.core_idx(crate::isa::CoreId(self.cfg.dma_core));
        let n_layers = self.g.nodes.len() as u16;
        self.push(dma_core, Instr::SpanBegin { layer: n_layers, class: LayerClass::DataMove });
        for t in self.g.inputs() {
            let td = self.g.tensor(t);
            let bytes = td.bytes();
            let mut src = self.alloc.ext(t);
            if self.alloc.pinned(t) {
                // Same per-inference pitch as `emit_output_store` —
                // producer and consumer address the handoff
                // identically by construction.
                src += inf * bytes.div_ceil(64) * 64;
            }
            let dst = self.alloc.spm(t, iter);
            self.emit_dma(dma_core, src, dst, 1, bytes, 0, 0, dma_dir::EXT_TO_SPM);
        }
        dma_core
    }

    /// DMA network outputs back to ext memory (region per inference
    /// `inf`; `iter` selects the double buffer).
    fn emit_output_store(&mut self, iter: u64, inf: u64) -> usize {
        let dma_core = self.core_idx(crate::isa::CoreId(self.cfg.dma_core));
        let n_layers = self.g.nodes.len() as u16;
        self.push(
            dma_core,
            Instr::SpanBegin { layer: n_layers + 1, class: LayerClass::DataMove },
        );
        for t in self.g.outputs() {
            let td = self.g.tensor(t);
            let bytes = td.bytes();
            let src = self.alloc.spm(t, iter);
            let dst = self.alloc.ext(t) + inf * bytes.div_ceil(64) * 64;
            self.emit_dma(dma_core, src, dst, 1, bytes, 0, 0, dma_dir::SPM_TO_EXT);
        }
        dma_core
    }

    /// Arrive at a per-inference system barrier (cross-cluster fence).
    fn emit_sys_fence(&mut self, base: u16, inf: u32, participants: u8) {
        let dma_core = self.core_idx(crate::isa::CoreId(self.cfg.dma_core));
        self.push(
            dma_core,
            Instr::Barrier { id: BarrierId(base + inf as u16), participants },
        );
    }

    fn emit_weight_load(&mut self, ni: NodeId) {
        let node = &self.g.nodes[ni.0];
        let Some(&wt) = node.inputs.get(1) else { return };
        if !matches!(self.g.tensor(wt).kind, TensorKind::Weight { .. }) {
            return;
        }
        let dma_core = self.core_idx(crate::isa::CoreId(self.cfg.dma_core));
        let src = self.alloc.ext(wt);
        let dst = self.alloc.weight_spm(wt, ni.0);
        let bytes = self.g.tensor(wt).bytes();
        self.emit_dma(dma_core, src, dst, 1, bytes, 0, 0, dma_dir::EXT_TO_SPM);
    }

    fn await_dma(&mut self, core: usize) {
        self.push(core, Instr::AwaitIdle { unit: self.cfg.dma_unit() });
    }

    fn end_dma_span(&mut self, core: usize, out: bool) {
        let n_layers = self.g.nodes.len() as u16;
        let layer = if out { n_layers + 1 } else { n_layers };
        self.push(core, Instr::SpanEnd { layer });
    }
}

/// Build the external-memory image: inputs and weights from their
/// seeds. Pinned handoff inputs get no bytes — the producing part of
/// the partitioned system writes them at runtime, fenced by the
/// system barrier ahead of every read.
fn ext_image(g: &Graph, alloc: &AllocMap) -> Vec<(u64, Vec<u8>)> {
    let mut init = Vec::new();
    for (ti, t) in g.tensors.iter().enumerate() {
        if alloc.ext_pinned[ti] {
            continue;
        }
        let seed = match t.kind {
            TensorKind::Input { seed } | TensorKind::Weight { seed } => seed,
            _ => continue,
        };
        let addr = alloc.ext_addr[ti].expect("io tensor has ext address");
        init.push((addr, lcg_bytes(seed, t.bytes() as usize)));
    }
    init
}

pub fn generate(input: &CodegenInput) -> Result<Program> {
    let g = input.graph;
    g.validate()?;
    let mut ctx = Ctx {
        g,
        cfg: input.cfg,
        place: input.placement,
        alloc: input.alloc,
        streams: vec![Vec::new(); input.cfg.cores.len()],
        descs: Vec::new(),
        next_barrier: 0,
        part_sync: input.sync,
    };
    match input.mode {
        Mode::Sequential => sequential(&mut ctx, input.n_inferences)?,
        Mode::Pipelined => pipelined(&mut ctx, input.n_inferences)?,
    }
    let mut layer_names: Vec<String> = g.nodes.iter().map(|n| n.name.clone()).collect();
    layer_names.push("dma_in".into());
    layer_names.push("dma_out".into());
    Ok(Program {
        streams: ctx.streams,
        ext_mem_init: ext_image(g, input.alloc),
        layer_names,
        descs: ctx.descs,
    })
}

/// Layer-by-layer execution with barrier separation. Weight streaming
/// overlaps the *next* layer's weight DMA with the current layer's
/// compute when two slots exist.
fn sequential(ctx: &mut Ctx, n_inferences: u32) -> Result<()> {
    let streamed = matches!(ctx.alloc.weight_mode, WeightMode::Streamed { .. });
    let two_slots = matches!(&ctx.alloc.weight_mode,
        WeightMode::Streamed { slots, .. } if slots.len() == 2);
    let n_nodes = ctx.g.nodes.len();
    let part_sync = ctx.part_sync;
    for inf in 0..n_inferences {
        // Cross-cluster handoff: wait until the producer part has
        // published this inference's inputs before DMA-ing them in.
        if let Some(ps) = &part_sync {
            if let Some(wb) = ps.wait_base {
                ctx.emit_sys_fence(wb, inf, ps.participants);
            }
        }
        // Inputs in. (Sequential mode uses buffer 0 everywhere.)
        let dma_core = ctx.emit_input_load(0, inf as u64);
        // Preload first layer's weights behind the input transfer.
        if streamed {
            ctx.emit_weight_load(NodeId(0));
        } else {
            // Resident weights: load them all once up-front (cheap to
            // re-issue per inference; the data is identical).
            for ni in 0..n_nodes {
                let node = &ctx.g.nodes[ni];
                if node.inputs.len() > 1
                    && matches!(ctx.g.tensor(node.inputs[1]).kind, TensorKind::Weight { .. })
                {
                    ctx.emit_weight_load(NodeId(ni));
                }
            }
        }
        ctx.await_dma(dma_core);
        ctx.end_dma_span(dma_core, false);
        ctx.sync();

        for ni in 0..n_nodes {
            let node_id = NodeId(ni);
            let device = ctx.place.devices[ni];
            let exec_core = ctx.emit_node_launch(node_id, 0)?;
            // Overlap: prefetch next streamed weights while this layer
            // runs (two slots), or serialize (one slot handled below).
            if streamed && two_slots && ni + 1 < n_nodes {
                ctx.emit_weight_load(NodeId(ni + 1));
            }
            if let Device::Accel(unit) = device {
                ctx.emit_node_await(node_id, exec_core, unit);
            }
            if streamed {
                let dc = ctx.core_idx(crate::isa::CoreId(ctx.cfg.dma_core));
                ctx.await_dma(dc);
                if !two_slots && ni + 1 < n_nodes {
                    // Single slot: next weights can only load after this
                    // layer finished (it reads the slot).
                    ctx.sync();
                    ctx.emit_weight_load(NodeId(ni + 1));
                    ctx.await_dma(dc);
                }
            }
            ctx.sync();
        }

        let dma_core = ctx.emit_output_store(0, inf as u64);
        ctx.await_dma(dma_core);
        ctx.end_dma_span(dma_core, true);
        // Handoff publish: signal the consumer part that this
        // inference's outputs are in external memory.
        if let Some(ps) = &part_sync {
            if let Some(sb) = ps.signal_base {
                ctx.emit_sys_fence(sb, inf, ps.participants);
            }
        }
        ctx.sync();
    }
    Ok(())
}

/// The unrolled virtual pipeline (paper Fig. 5): stages = [input DMA,
/// node 0, ..., node N-1, output DMA]; stage `s` handles inference
/// `t - s` in tick `t`; all cores barrier between ticks.
fn pipelined(ctx: &mut Ctx, n_inferences: u32) -> Result<()> {
    if ctx.part_sync.is_some() {
        bail!("cross-cluster handoff fences require sequential part programs");
    }
    if matches!(ctx.alloc.weight_mode, WeightMode::Streamed { .. }) {
        bail!(
            "pipelined mode requires resident weights (per-layer weight \
             streaming would serialize the pipeline); graph '{}' overflows SPM",
            ctx.g.name
        );
    }
    if !ctx.alloc.double_buffered {
        bail!("pipelined mode requires double-buffered activations");
    }
    let n_nodes = ctx.g.nodes.len();
    let n_stages = n_nodes + 2;
    let dma_core = ctx.core_idx(crate::isa::CoreId(ctx.cfg.dma_core));

    // Load all weights once.
    for ni in 0..n_nodes {
        let node = &ctx.g.nodes[ni];
        if node.inputs.len() > 1
            && matches!(ctx.g.tensor(node.inputs[1]).kind, TensorKind::Weight { .. })
        {
            ctx.emit_weight_load(NodeId(ni));
        }
    }
    ctx.await_dma(dma_core);
    ctx.sync();

    let ticks = n_inferences as u64 + n_stages as u64 - 1;
    for t in 0..ticks {
        // Phase A: launches + CPU kernels. Accel launches first so the
        // units run while CPU stages execute (asynchronous control).
        let mut awaits: Vec<(NodeId, usize, UnitId)> = Vec::new();
        let mut dma_busy = false;
        // Input DMA stage (s = 0) handles inference t.
        if t < n_inferences as u64 {
            ctx.emit_input_load(t, t);
            dma_busy = true;
        }
        // Node stages s = 1..=n_nodes handle inference t - s.
        for ni in 0..n_nodes {
            let s = ni as u64 + 1;
            if t < s {
                continue;
            }
            let inf = t - s;
            if inf >= n_inferences as u64 {
                continue;
            }
            let node_id = NodeId(ni);
            let device = ctx.place.devices[ni];
            match device {
                Device::Accel(unit) => {
                    let core = ctx.emit_node_launch(node_id, inf)?;
                    awaits.push((node_id, core, unit));
                }
                Device::Cpu(_) => {
                    // CPU kernels are emitted in phase A too — the core
                    // blocks on them after issuing its launches; that is
                    // exactly the paper's "FC on the RISC-V core while
                    // accelerators run" overlap.
                    ctx.emit_node_launch(node_id, inf)?;
                }
            }
        }
        // Output DMA stage (s = n_stages-1) handles inference t-s.
        let s_out = n_stages as u64 - 1;
        if t >= s_out && t - s_out < n_inferences as u64 {
            ctx.emit_output_store(t - s_out, t - s_out);
            dma_busy = true;
        }
        // Phase B: awaits, then the tick barrier.
        for (node_id, core, unit) in awaits {
            ctx.emit_node_await(node_id, core, unit);
        }
        if dma_busy {
            ctx.await_dma(dma_core);
            ctx.end_dma_span(dma_core, t >= s_out);
        }
        ctx.sync();
    }
    Ok(())
}
