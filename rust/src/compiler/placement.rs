//! Device placement (SNAX-MLIR pass 1, paper Fig. 5.1).
//!
//! Each workload node is assigned to the most suited device based on
//! the cluster's accelerator descriptions: GeMM-shaped ops (conv/dense)
//! to a GeMM accelerator, pooling to a pool unit, elementwise adds to a
//! vector unit — each falling back to a management core when no
//! matching accelerator exists ("minimizing off-cluster data movement").

use crate::config::{AccelKind, ClusterConfig};
use crate::isa::{CoreId, UnitId};

use super::ir::{Graph, OpKind};

/// Where a node runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    Accel(UnitId),
    Cpu(CoreId),
}

#[derive(Debug, Clone)]
pub struct Placement {
    /// Indexed by node id.
    pub devices: Vec<Device>,
    /// The core chosen for software fallback kernels.
    pub cpu_core: CoreId,
}

impl Placement {
    pub fn device(&self, node: super::ir::NodeId) -> Device {
        self.devices[node.0]
    }

    pub fn n_accel_nodes(&self) -> usize {
        self.devices.iter().filter(|d| matches!(d, Device::Accel(_))).count()
    }
}

/// Pick the fallback core: the one managing the fewest units has the
/// most spare issue slots for software kernels.
fn pick_cpu_core(cfg: &ClusterConfig) -> CoreId {
    let mut load: Vec<(usize, u8)> = cfg
        .cores
        .iter()
        .map(|c| {
            let n = cfg.accelerators.iter().filter(|a| a.core == c.id).count()
                + usize::from(cfg.dma_core == c.id);
            (n, c.id)
        })
        .collect();
    load.sort();
    CoreId(load[0].1)
}

/// Per-op overrides (used by ablation benches to force CPU execution).
#[derive(Debug, Clone, Default)]
pub struct PlacementOverrides {
    /// Node names forced onto the CPU.
    pub force_cpu: Vec<String>,
}

/// Can this node actually run on the accelerator kind? The GeMM array
/// steps in 8x8x8 tiles and the pool unit has 8 lanes; incompatible
/// sections fall back to the core (paper: "for workload sections that
/// are incompatible with the available accelerators, the accompanying
/// RISC-V core handles execution").
fn compatible(g: &Graph, n: &super::ir::Node, kind: AccelKind) -> bool {
    let aligned = |v: u32| v % 8 == 0;
    match (kind, &n.kind) {
        (AccelKind::Gemm, OpKind::Dense { .. }) => {
            let wd = g.tensor(n.inputs[1]);
            let m = g.tensor(n.output).dims[0];
            aligned(m) && aligned(wd.dims[0]) && aligned(wd.dims[1])
        }
        (AccelKind::Gemm, OpKind::Conv2d { kh, kw, .. }) => {
            let xd = g.tensor(n.inputs[0]);
            let od = g.tensor(n.output);
            let m = od.dims[0] * od.dims[1] * od.dims[2];
            let k = kh * kw * xd.dims[3];
            aligned(m) && aligned(k) && aligned(od.dims[3])
        }
        (AccelKind::MaxPool, OpKind::MaxPool2d { .. }) => {
            aligned(g.tensor(n.inputs[0]).dims[3])
        }
        (AccelKind::VecAdd, OpKind::ResidualAdd { .. }) => true,
        _ => false,
    }
}

pub fn place(g: &Graph, cfg: &ClusterConfig, ov: &PlacementOverrides) -> Placement {
    let cpu_core = pick_cpu_core(cfg);
    // Round-robin counters per accelerator kind: when a cluster carries
    // several instances of one kind, compatible nodes are distributed
    // across them so pipeline stages can execute concurrently.
    let mut rr: std::collections::HashMap<AccelKind, usize> = Default::default();
    let devices = g
        .nodes
        .iter()
        .map(|n| {
            if ov.force_cpu.iter().any(|f| f == &n.name) {
                return Device::Cpu(cpu_core);
            }
            let kind = match n.kind {
                OpKind::Conv2d { .. } | OpKind::Dense { .. } => Some(AccelKind::Gemm),
                OpKind::MaxPool2d { .. } => Some(AccelKind::MaxPool),
                OpKind::ResidualAdd { .. } => Some(AccelKind::VecAdd),
                OpKind::GlobalAvgPool | OpKind::TileRows { .. } => None,
            };
            let Some(k) = kind else { return Device::Cpu(cpu_core) };
            let instances = cfg.find_accels(k);
            if instances.is_empty() || !compatible(g, n, k) {
                return Device::Cpu(cpu_core);
            }
            let slot = rr.entry(k).or_insert(0);
            let unit = instances[*slot % instances.len()].0;
            *slot += 1;
            Device::Accel(unit)
        })
        .collect();
    Placement { devices, cpu_core }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::compiler::ir::Graph;

    #[test]
    fn misaligned_dense_falls_back_to_cpu() {
        // M=1 dense cannot run on the 8x8x8 PE array.
        let mut g = Graph::new("m1");
        let x = g.add_input("x", &[1, 128], 1);
        let d = g.dense("fc", x, 8, false, 0, true, 2).unwrap();
        g.mark_output(d);
        let p = place(&g, &ClusterConfig::fig6c(), &Default::default());
        assert!(matches!(p.devices[0], Device::Cpu(_)));
    }

    fn g() -> Graph {
        let mut g = Graph::new("t");
        let x = g.add_input("x", &[1, 16, 16, 8], 1);
        let c = g.conv2d("conv", x, 8, 3, 3, 1, 1, true, 8, 2).unwrap();
        let p = g.maxpool2d("pool", c, 2, 2).unwrap();
        let a = g.residual_add("add", p, p, false).unwrap();
        let t = g.tile_rows("tile", a, 8).unwrap(); // make fc 8-row aligned
        let d = g.dense("fc", t, 8, false, 0, true, 3).unwrap();
        g.mark_output(d);
        g
    }

    #[test]
    fn fig6b_everything_on_cpu() {
        let p = place(&g(), &ClusterConfig::fig6b(), &Default::default());
        assert_eq!(p.n_accel_nodes(), 0);
        assert_eq!(p.cpu_core, CoreId(0));
    }

    #[test]
    fn fig6c_gemm_ops_offloaded() {
        let cfg = ClusterConfig::fig6c();
        let p = place(&g(), &cfg, &Default::default());
        // conv + dense on gemm, pool/add/tile on cpu
        assert_eq!(p.devices[0], Device::Accel(cfg.unit_id("gemm0").unwrap()));
        assert_eq!(p.devices[4], Device::Accel(cfg.unit_id("gemm0").unwrap()));
        assert!(matches!(p.devices[1], Device::Cpu(_)));
        assert!(matches!(p.devices[2], Device::Cpu(_)));
        assert!(matches!(p.devices[3], Device::Cpu(_)));
        // Core 1 controls only the gemm; core 0 controls the DMA — both
        // have one unit, tie broken to lowest id.
        assert_eq!(p.cpu_core, CoreId(0));
    }

    #[test]
    fn fig6d_pool_offloaded_and_cpu_is_least_loaded() {
        let cfg = ClusterConfig::fig6d();
        let p = place(&g(), &cfg, &Default::default());
        assert_eq!(p.devices[1], Device::Accel(cfg.unit_id("maxpool0").unwrap()));
        // core0 manages dma+maxpool (2), core1 manages gemm (1).
        assert_eq!(p.cpu_core, CoreId(1));
    }

    #[test]
    fn overrides_force_cpu() {
        let cfg = ClusterConfig::fig6d();
        let ov = PlacementOverrides { force_cpu: vec!["conv".into()] };
        let p = place(&g(), &cfg, &ov);
        assert!(matches!(p.devices[0], Device::Cpu(_)));
        assert!(matches!(p.devices[4], Device::Accel(_)));
    }
}
