//! Pass 0 — cross-cluster partitioning (the SoC-level pass ahead of
//! Fig. 5's per-cluster pipeline).
//!
//! Splits one workload [`Graph`] across the clusters of a
//! [`SystemConfig`], then runs the existing placement / allocation /
//! codegen passes per part:
//!
//! * **Pipeline** — a contiguous layer range per cluster, balanced by
//!   the accelerator-aware cost model ([`super::cost::node_cost`]).
//!   Stage `k` hands its boundary tensors to stage `k+1` through
//!   external memory: the producer's output store and the consumer's
//!   input load address the *same* per-inference region (the consumer's
//!   input tensor is ext-**pinned** to the producer's output address),
//!   fenced by per-inference system barriers so the read can never
//!   overtake the write. Stage `k` computes inference `i+1` while stage
//!   `k+1` computes inference `i` — inference-level pipelining across
//!   clusters.
//! * **DataParallel** — every cluster runs the whole graph over its
//!   share of the inference batch (batch sharding). No cross-cluster
//!   data dependencies; clusters interact only through shared-NoC
//!   contention.
//!
//! A system-of-1 (or [`PartitionStrategy::None`]) degenerates to the
//! plain [`compile`] path, so the single-cluster flow is a strict
//! subset of the system flow.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::config::SystemConfig;
use crate::isa::{Program, SYS_BARRIER_BASE};
use crate::sim::SystemReport;

use super::alloc::allocate_system;
use super::codegen::{self, CodegenInput, Mode, PartSync};
use super::cost::node_cost;
use super::ir::{DType, Graph, OpKind, TensorId, TensorKind};
use super::placement;
use super::{compile, CompileOptions, CompiledProgram};

/// How to split a graph across the system's clusters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionStrategy {
    /// No split — only valid for systems of one cluster.
    #[default]
    None,
    /// Layer-pipelined: one contiguous stage per cluster, ext-mem
    /// handoffs + system barriers between stages.
    Pipeline,
    /// Batch-sharded: each cluster runs the full graph over its share
    /// of the inferences.
    DataParallel,
}

impl PartitionStrategy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(Self::None),
            "pipeline" => Ok(Self::Pipeline),
            "data" | "data-parallel" | "dp" => Ok(Self::DataParallel),
            other => bail!("unknown partition strategy '{other}' (expected none|pipeline|data)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Pipeline => "pipeline",
            Self::DataParallel => "data",
        }
    }

    /// The sensible default for a system: pipeline when there is more
    /// than one cluster, otherwise no split.
    pub fn default_for(sys: &SystemConfig) -> Self {
        if sys.n_clusters() > 1 {
            Self::Pipeline
        } else {
            Self::None
        }
    }
}

/// Metadata of one compiled part.
#[derive(Debug, Clone)]
pub struct PartPlan {
    pub cluster: String,
    /// Original-graph node range this part covers.
    pub node_range: (usize, usize),
    pub n_inferences: u32,
    /// First global inference this part handles (DataParallel).
    pub inf_offset: u32,
    /// Start of this part's region in the shared external memory.
    pub ext_base: u64,
}

/// The partition decision, for reports and result lookup.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    pub strategy: PartitionStrategy,
    pub parts: Vec<PartPlan>,
}

/// A workload compiled for a whole system: one [`CompiledProgram`] per
/// cluster (system order) plus the partition plan.
pub struct CompiledSystem {
    /// Original (unpartitioned) workload name.
    pub net: String,
    pub system: SystemConfig,
    pub parts: Vec<CompiledProgram>,
    pub plan: PartitionPlan,
}

impl CompiledSystem {
    /// Part programs in system order (the shape [`crate::sim::System::run`]
    /// takes).
    pub fn programs(&self) -> Vec<&Program> {
        self.parts.iter().map(|p| &p.program).collect()
    }

    /// Total inferences the system executes (global batch).
    pub fn n_inferences(&self) -> u32 {
        match self.plan.strategy {
            PartitionStrategy::DataParallel => {
                self.plan.parts.iter().map(|p| p.n_inferences).sum()
            }
            _ => self.plan.parts.first().map(|p| p.n_inferences).unwrap_or(0),
        }
    }

    /// Read the bytes of output tensor `idx` for global inference `inf`
    /// from a finished system run's shared external memory.
    pub fn read_output(&self, rep: &SystemReport, idx: usize, inf: u64) -> Vec<u8> {
        let (part, local_inf) = match self.plan.strategy {
            PartitionStrategy::DataParallel => {
                let p = self
                    .plan
                    .parts
                    .iter()
                    .position(|p| {
                        (p.inf_offset as u64..p.inf_offset as u64 + p.n_inferences as u64)
                            .contains(&inf)
                    })
                    .expect("inference within the compiled batch");
                (p, inf - self.plan.parts[p].inf_offset as u64)
            }
            // Pipeline / None: the last part produces the original
            // graph outputs.
            _ => (self.parts.len() - 1, inf),
        };
        let cp = &self.parts[part];
        let t = cp.graph.outputs()[idx];
        let bytes = cp.graph.tensor(t).bytes();
        let addr = cp.alloc.ext(t) + local_inf * bytes.div_ceil(64) * 64;
        rep.read_ext(addr, bytes as usize).to_vec()
    }
}

/// Parts place their regions on 4 KiB boundaries of the shared memory.
const EXT_BASE_ALIGN: u64 = 4096;

/// Run pass 0 and compile every part.
pub fn compile_system(
    graph: &Graph,
    sys: &SystemConfig,
    options: &CompileOptions,
    strategy: PartitionStrategy,
) -> Result<CompiledSystem> {
    sys.validate()?;
    graph.validate().with_context(|| format!("validating graph '{}'", graph.name))?;
    let n = sys.n_clusters();
    if n == 1 {
        if strategy != PartitionStrategy::None {
            bail!(
                "partition strategy '{}' needs a multi-cluster system — \
                 '{}' has one cluster (drop the strategy or use none)",
                strategy.name(),
                sys.name
            );
        }
        // Degenerate system-of-1: the plain single-cluster pipeline.
        let cp = compile(graph, &sys.clusters[0], options)?;
        let plan = PartitionPlan {
            strategy: PartitionStrategy::None,
            parts: vec![PartPlan {
                cluster: sys.clusters[0].name.clone(),
                node_range: (0, graph.nodes.len()),
                n_inferences: options.n_inferences,
                inf_offset: 0,
                ext_base: 0,
            }],
        };
        return Ok(CompiledSystem {
            net: graph.name.clone(),
            system: sys.clone(),
            parts: vec![cp],
            plan,
        });
    }
    match strategy {
        PartitionStrategy::Pipeline => pipeline_parts(graph, sys, options),
        PartitionStrategy::DataParallel => data_parallel_parts(graph, sys, options),
        PartitionStrategy::None => bail!(
            "system '{}' has {n} clusters — pick a partition strategy (pipeline|data)",
            sys.name
        ),
    }
}

// ---------------------------------------------------------------------------
// Pipeline partitioning
// ---------------------------------------------------------------------------

/// Can the graph be cut between nodes `c-1` and `c`? Every tensor
/// crossing the boundary must be int8 (handoff tensors become int8
/// stage inputs).
fn cut_feasible(g: &Graph, c: usize) -> bool {
    g.nodes.iter().take(c).all(|node| {
        let t = node.output;
        let crosses = g.nodes[c..].iter().any(|n2| n2.inputs.contains(&t));
        !crosses || g.tensor(t).dtype == DType::I8
    })
}

/// Choose `n` contiguous stage ranges minimizing the maximum per-stage
/// cost, where stage `k`'s cost is evaluated with cluster `k`'s
/// accelerator-aware cost model. Exact DP over (stage, cut) — graphs
/// here have tens of nodes, so O(n·m²) is trivial.
fn balanced_cuts(g: &Graph, sys: &SystemConfig) -> Result<Vec<usize>> {
    let n = sys.n_clusters();
    let m = g.nodes.len();
    ensure!(m >= n, "graph '{}' has {m} nodes — fewer than {n} pipeline stages", g.name);
    let prefix: Vec<Vec<u64>> = sys
        .clusters
        .iter()
        .map(|cfg| {
            let mut p = vec![0u64; m + 1];
            for (i, node) in g.nodes.iter().enumerate() {
                p[i + 1] = p[i] + node_cost(g, node, cfg);
            }
            p
        })
        .collect();
    let feasible: Vec<bool> = (0..=m).map(|c| cut_feasible(g, c)).collect();
    const INF: u64 = u64::MAX;
    let mut best = vec![vec![INF; m + 1]; n + 1];
    let mut back = vec![vec![0usize; m + 1]; n + 1];
    best[0][0] = 0;
    for k in 1..=n {
        for j in k..=m {
            if j != m && !feasible[j] {
                continue;
            }
            for i in (k - 1)..j {
                if best[k - 1][i] == INF {
                    continue;
                }
                let stage_cost = prefix[k - 1][j] - prefix[k - 1][i];
                let v = best[k - 1][i].max(stage_cost);
                if v < best[k][j] {
                    best[k][j] = v;
                    back[k][j] = i;
                }
            }
        }
    }
    if best[n][m] == INF {
        bail!(
            "no feasible {n}-way pipeline cut of '{}' (an int32 tensor crosses \
             every candidate boundary)",
            g.name
        );
    }
    let mut cuts = vec![m];
    let mut j = m;
    for k in (1..=n).rev() {
        j = back[k][j];
        cuts.push(j);
    }
    cuts.reverse();
    Ok(cuts)
}

/// One extracted pipeline stage.
struct Stage {
    graph: Graph,
    /// (stage input tensor, original tensor) for every cross-cut input
    /// — these get ext-pinned to the producer stage's output region.
    cross_inputs: Vec<(TensorId, TensorId)>,
    /// (stage tensor, original tensor) for every tensor this stage
    /// publishes to external memory (handoffs + original outputs).
    out_map: Vec<(TensorId, TensorId)>,
}

fn stage_input(
    sg: &mut Graph,
    map: &mut HashMap<TensorId, TensorId>,
    cross_inputs: &mut Vec<(TensorId, TensorId)>,
    g: &Graph,
    t: TensorId,
) -> Result<TensorId> {
    if let Some(&m) = map.get(&t) {
        return Ok(m);
    }
    let td = g.tensor(t);
    let nt = match td.kind {
        // An original network input: rebuilt with its real seed (the
        // part materializes the same deterministic bytes).
        TensorKind::Input { seed } => sg.add_input(&td.name, &td.dims, seed),
        // Produced by an earlier stage: becomes a pinned handoff input
        // (seed 0 is never materialized — the producer writes the
        // bytes at runtime).
        TensorKind::Intermediate | TensorKind::Output => {
            ensure!(
                td.dtype == DType::I8,
                "cannot hand off int32 tensor '{}' across clusters",
                td.name
            );
            let nt = sg.add_input(&td.name, &td.dims, 0);
            cross_inputs.push((nt, t));
            nt
        }
        TensorKind::Weight { .. } => {
            bail!("weight tensor '{}' used as activation", td.name)
        }
    };
    map.insert(t, nt);
    Ok(nt)
}

fn weight_seed(g: &Graph, t: TensorId) -> Result<u64> {
    match g.tensor(t).kind {
        TensorKind::Weight { seed } => Ok(seed),
        _ => bail!("node weight input '{}' is not a weight tensor", g.tensor(t).name),
    }
}

/// Rebuild nodes `lo..hi` of `g` as a standalone stage graph.
fn extract_stage(g: &Graph, lo: usize, hi: usize, stage_idx: usize) -> Result<Stage> {
    let mut sg = Graph::new(&format!("{}.p{stage_idx}", g.name));
    let mut map: HashMap<TensorId, TensorId> = HashMap::new();
    let mut cross_inputs = Vec::new();
    for ni in lo..hi {
        let node = &g.nodes[ni];
        let x = stage_input(&mut sg, &mut map, &mut cross_inputs, g, node.inputs[0])?;
        let out = match node.kind {
            OpKind::Conv2d { kh, kw, stride, pad, relu, shift } => {
                let wd = g.tensor(node.inputs[1]);
                sg.conv2d(
                    &node.name,
                    x,
                    wd.dims[1],
                    kh,
                    kw,
                    stride,
                    pad,
                    relu,
                    shift,
                    weight_seed(g, node.inputs[1])?,
                )?
            }
            OpKind::Dense { relu, shift, logits } => {
                let wd = g.tensor(node.inputs[1]);
                sg.dense(
                    &node.name,
                    x,
                    wd.dims[1],
                    relu,
                    shift,
                    logits,
                    weight_seed(g, node.inputs[1])?,
                )?
            }
            OpKind::MaxPool2d { k, s } => sg.maxpool2d(&node.name, x, k, s)?,
            OpKind::GlobalAvgPool => sg.global_avgpool(&node.name, x)?,
            OpKind::ResidualAdd { relu } => {
                let b = stage_input(&mut sg, &mut map, &mut cross_inputs, g, node.inputs[1])?;
                sg.residual_add(&node.name, x, b, relu)?
            }
            OpKind::TileRows { rows } => sg.tile_rows(&node.name, x, rows)?,
        };
        let od = g.tensor(node.output);
        ensure!(
            sg.tensor(out).dims == od.dims && sg.tensor(out).dtype == od.dtype,
            "stage rebuild of '{}' changed its output shape",
            node.name
        );
        map.insert(node.output, out);
    }
    // Publish: original outputs produced here, plus every tensor a
    // later stage consumes.
    let mut out_map = Vec::new();
    for ni in lo..hi {
        let t = g.nodes[ni].output;
        let consumed_later = g.nodes[hi..].iter().any(|n2| n2.inputs.contains(&t));
        let is_output = matches!(g.tensor(t).kind, TensorKind::Output);
        if consumed_later || is_output {
            let st = map[&t];
            sg.mark_output(st);
            out_map.push((st, t));
        }
    }
    sg.validate().with_context(|| format!("extracted stage {stage_idx}"))?;
    Ok(Stage { graph: sg, cross_inputs, out_map })
}

/// Next part base: past this part's layout (which already reserves the
/// per-inference output rooms), 4 KiB-aligned.
fn next_ext_base(alloc_end: u64) -> u64 {
    alloc_end.div_ceil(EXT_BASE_ALIGN) * EXT_BASE_ALIGN
}

fn pipeline_parts(
    graph: &Graph,
    sys: &SystemConfig,
    options: &CompileOptions,
) -> Result<CompiledSystem> {
    if options.mode == Mode::Pipelined {
        bail!(
            "pipeline partitioning already pipelines across clusters; \
             each stage compiles sequentially (drop --pipelined)"
        );
    }
    let n = sys.n_clusters();
    let n_inf = options.n_inferences.max(1);
    let boundaries = (n - 1) as u64;
    if boundaries * n_inf as u64 > (u16::MAX - SYS_BARRIER_BASE) as u64 + 1 {
        bail!(
            "pipeline needs {} system-barrier ids but only {} exist — \
             reduce --inferences or stages",
            boundaries * n_inf as u64,
            (u16::MAX - SYS_BARRIER_BASE) as u64 + 1
        );
    }
    let cuts = balanced_cuts(graph, sys)?;
    let mut parts = Vec::with_capacity(n);
    let mut plans = Vec::with_capacity(n);
    let mut ext_base = 0u64;
    // Original tensor -> absolute ext address of its published region.
    let mut published: HashMap<TensorId, u64> = HashMap::new();
    for k in 0..n {
        let (lo, hi) = (cuts[k], cuts[k + 1]);
        let stage = extract_stage(graph, lo, hi, k)?;
        let pins: Vec<(TensorId, u64)> = stage
            .cross_inputs
            .iter()
            .map(|&(st, orig)| {
                published
                    .get(&orig)
                    .copied()
                    .map(|addr| (st, addr))
                    .with_context(|| {
                        format!(
                            "handoff tensor '{}' not published by an earlier stage",
                            graph.tensor(orig).name
                        )
                    })
            })
            .collect::<Result<_>>()?;
        let cfg = &sys.clusters[k];
        let place = placement::place(&stage.graph, cfg, &options.overrides);
        let alloc = allocate_system(
            &stage.graph,
            cfg,
            false,
            options.max_weight_slots,
            ext_base,
            &pins,
            n_inf,
        )
        .with_context(|| format!("allocating stage {k} on '{}'", cfg.name))?;
        for &(st, orig) in &stage.out_map {
            published.insert(orig, alloc.ext(st));
        }
        let fence = |b: usize| SYS_BARRIER_BASE + (b as u16) * n_inf as u16;
        let wait_base = if k > 0 { Some(fence(k - 1)) } else { None };
        let signal_base = if k + 1 < n { Some(fence(k)) } else { None };
        let sync = PartSync { wait_base, signal_base, participants: 2 };
        let program = codegen::generate(&CodegenInput {
            graph: &stage.graph,
            cfg,
            placement: &place,
            alloc: &alloc,
            mode: Mode::Sequential,
            n_inferences: n_inf,
            sync: Some(sync),
        })
        .with_context(|| format!("generating stage {k} for '{}'", cfg.name))?;
        plans.push(PartPlan {
            cluster: cfg.name.clone(),
            node_range: (lo, hi),
            n_inferences: n_inf,
            inf_offset: 0,
            ext_base,
        });
        ext_base = next_ext_base(alloc.ext_used);
        let mut part_opts = options.clone();
        part_opts.mode = Mode::Sequential;
        part_opts.n_inferences = n_inf;
        parts.push(CompiledProgram {
            program,
            placement: place,
            alloc,
            graph: stage.graph,
            options: part_opts,
        });
    }
    Ok(CompiledSystem {
        net: graph.name.clone(),
        system: sys.clone(),
        parts,
        plan: PartitionPlan { strategy: PartitionStrategy::Pipeline, parts: plans },
    })
}

// ---------------------------------------------------------------------------
// Data-parallel partitioning
// ---------------------------------------------------------------------------

fn data_parallel_parts(
    graph: &Graph,
    sys: &SystemConfig,
    options: &CompileOptions,
) -> Result<CompiledSystem> {
    let n = sys.n_clusters() as u32;
    let total = options.n_inferences;
    if total < n {
        bail!(
            "data-parallel partitioning needs at least one inference per cluster \
             ({total} inferences over {n} clusters)"
        );
    }
    let mut parts = Vec::with_capacity(n as usize);
    let mut plans = Vec::with_capacity(n as usize);
    let mut ext_base = 0u64;
    let mut offset = 0u32;
    for k in 0..n {
        let share = total / n + u32::from(k < total % n);
        let mut gk = graph.clone();
        gk.name = format!("{}.d{k}", graph.name);
        let cfg = &sys.clusters[k as usize];
        let place = placement::place(&gk, cfg, &options.overrides);
        let double_buffer = options.mode == Mode::Pipelined;
        let alloc = allocate_system(
            &gk,
            cfg,
            double_buffer,
            options.max_weight_slots,
            ext_base,
            &[],
            share,
        )
        .with_context(|| format!("allocating shard {k} on '{}'", cfg.name))?;
        let program = codegen::generate(&CodegenInput {
            graph: &gk,
            cfg,
            placement: &place,
            alloc: &alloc,
            mode: options.mode,
            n_inferences: share,
            sync: None,
        })
        .with_context(|| format!("generating shard {k} for '{}'", cfg.name))?;
        plans.push(PartPlan {
            cluster: cfg.name.clone(),
            node_range: (0, graph.nodes.len()),
            n_inferences: share,
            inf_offset: offset,
            ext_base,
        });
        ext_base = next_ext_base(alloc.ext_used);
        offset += share;
        let mut part_opts = options.clone();
        part_opts.n_inferences = share;
        parts.push(CompiledProgram {
            program,
            placement: place,
            alloc,
            graph: gk,
            options: part_opts,
        });
    }
    Ok(CompiledSystem {
        net: graph.name.clone(),
        system: sys.clone(),
        parts,
        plan: PartitionPlan { strategy: PartitionStrategy::DataParallel, parts: plans },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SystemConfig};
    use crate::models;

    #[test]
    fn strategy_parsing() {
        assert_eq!(PartitionStrategy::parse("none").unwrap(), PartitionStrategy::None);
        assert_eq!(
            PartitionStrategy::parse("pipeline").unwrap(),
            PartitionStrategy::Pipeline
        );
        assert_eq!(
            PartitionStrategy::parse("data").unwrap(),
            PartitionStrategy::DataParallel
        );
        assert!(PartitionStrategy::parse("zig").is_err());
        assert_eq!(
            PartitionStrategy::default_for(&SystemConfig::soc2()),
            PartitionStrategy::Pipeline
        );
        assert_eq!(
            PartitionStrategy::default_for(&SystemConfig::preset("fig6d").unwrap()),
            PartitionStrategy::None
        );
    }

    #[test]
    fn system_of_one_degenerates_to_plain_compile() {
        let g = models::fig6a_graph();
        let sys = SystemConfig::single(ClusterConfig::fig6d());
        let opts = CompileOptions::sequential();
        let cs = compile_system(&g, &sys, &opts, PartitionStrategy::None).unwrap();
        let cp = compile(&g, &sys.clusters[0], &opts).unwrap();
        assert_eq!(cs.parts.len(), 1);
        assert_eq!(cs.parts[0].program.n_instrs(), cp.program.n_instrs());
        assert_eq!(cs.parts[0].program.ext_mem_init, cp.program.ext_mem_init);
        assert_eq!(cs.plan.strategy, PartitionStrategy::None);
    }

    #[test]
    fn multi_cluster_requires_a_strategy() {
        let g = models::fig6a_graph();
        let sys = SystemConfig::soc2();
        let err = compile_system(&g, &sys, &CompileOptions::sequential(), PartitionStrategy::None)
            .unwrap_err();
        assert!(err.to_string().contains("partition strategy"), "{err}");
        // The converse is also explicit: a strategy on a system-of-1
        // is an error, never a silent no-op.
        let one = SystemConfig::preset("fig6d").unwrap();
        let err = compile_system(
            &g,
            &one,
            &CompileOptions::sequential(),
            PartitionStrategy::Pipeline,
        )
        .unwrap_err();
        assert!(err.to_string().contains("multi-cluster"), "{err}");
    }

    #[test]
    fn pipeline_cut_builds_fenced_handoff_parts() {
        let g = models::resnet8_graph();
        let sys = SystemConfig::soc2();
        let opts = CompileOptions::sequential().with_inferences(2);
        let cs = compile_system(&g, &sys, &opts, PartitionStrategy::Pipeline).unwrap();
        assert_eq!(cs.parts.len(), 2);
        // Contiguous full cover.
        assert_eq!(cs.plan.parts[0].node_range.0, 0);
        assert_eq!(cs.plan.parts[0].node_range.1, cs.plan.parts[1].node_range.0);
        assert_eq!(cs.plan.parts[1].node_range.1, g.nodes.len());
        // Disjoint ext regions.
        assert!(cs.plan.parts[1].ext_base > 0);
        assert!(cs.parts[0].alloc.ext_used <= cs.plan.parts[1].ext_base);
        // Stage 1's handoff input is pinned into stage 0's region and
        // carries no init bytes.
        let p1 = &cs.parts[1];
        let pinned: Vec<_> =
            p1.graph.inputs().into_iter().filter(|&t| p1.alloc.pinned(t)).collect();
        assert!(!pinned.is_empty(), "stage 1 must have a pinned handoff input");
        for &t in &pinned {
            assert!(p1.alloc.ext(t) < cs.plan.parts[1].ext_base);
            let addr = p1.alloc.ext(t);
            assert!(
                !p1.program.ext_mem_init.iter().any(|(a, _)| *a == addr),
                "pinned input must not be materialized in the image"
            );
        }
        // The fences pair up: stage 0 signals the ids stage 1 awaits.
        let ids = |p: &crate::isa::Program| -> Vec<u16> {
            let mut v: Vec<u16> = p
                .streams
                .iter()
                .flatten()
                .filter_map(|i| match i {
                    crate::isa::Instr::Barrier { id, .. } if id.0 >= SYS_BARRIER_BASE => {
                        Some(id.0)
                    }
                    _ => None,
                })
                .collect();
            v.sort_unstable();
            v
        };
        let s0 = ids(&cs.parts[0].program);
        let s1 = ids(&cs.parts[1].program);
        assert_eq!(s0, s1, "producer and consumer must share fence ids");
        assert_eq!(s0.len(), 2, "one fence per inference per boundary");
    }

    #[test]
    fn data_parallel_shards_the_batch() {
        let g = models::fig6a_graph();
        let sys = SystemConfig::soc4();
        let opts = CompileOptions::sequential().with_inferences(6);
        let cs = compile_system(&g, &sys, &opts, PartitionStrategy::DataParallel).unwrap();
        assert_eq!(cs.parts.len(), 4);
        let shares: Vec<u32> = cs.plan.parts.iter().map(|p| p.n_inferences).collect();
        assert_eq!(shares, vec![2, 2, 1, 1]);
        let offsets: Vec<u32> = cs.plan.parts.iter().map(|p| p.inf_offset).collect();
        assert_eq!(offsets, vec![0, 2, 4, 5]);
        assert_eq!(cs.n_inferences(), 6);
        // Bases strictly increase and regions stay disjoint.
        for w in cs.plan.parts.windows(2) {
            assert!(w[0].ext_base < w[1].ext_base);
        }
        // Too few inferences is rejected.
        let err = compile_system(
            &g,
            &sys,
            &CompileOptions::sequential().with_inferences(2),
            PartitionStrategy::DataParallel,
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one inference"), "{err}");
    }

    #[test]
    fn pipelined_mode_is_rejected_for_pipeline_strategy() {
        let g = models::fig6a_graph();
        let err = compile_system(
            &g,
            &SystemConfig::soc2(),
            &CompileOptions::pipelined(),
            PartitionStrategy::Pipeline,
        )
        .unwrap_err();
        assert!(err.to_string().contains("--pipelined"), "{err}");
    }
}
