//! RV32I software cost model — cycle estimates for workload sections
//! that fall back to a management core (the paper's CPU path).
//!
//! Constants live in [`crate::energy::calib`] with their anchors.

use crate::energy::calib::*;

use super::ir::{Graph, Node, OpKind};

/// Cycles for node `n` executed in software on a management core.
pub fn cpu_cycles(g: &Graph, n: &Node) -> u64 {
    let out = g.tensor(n.output);
    let base = match n.kind {
        OpKind::Conv2d { kh, kw, .. } => {
            let wd = g.tensor(n.inputs[1]);
            let cin = (wd.dims[0] / (kh * kw)) as u64;
            out.elems() * kh as u64 * kw as u64 * cin * CPU_MAC_CONV
        }
        OpKind::Dense { .. } => {
            let wd = g.tensor(n.inputs[1]);
            out.elems() * wd.dims[0] as u64 * CPU_MAC_FC
        }
        OpKind::MaxPool2d { k, .. } => out.elems() * (k as u64 * k as u64) * CPU_POOL_OP,
        OpKind::GlobalAvgPool => {
            let xd = g.tensor(n.inputs[0]);
            xd.elems() * CPU_AVG
        }
        OpKind::ResidualAdd { .. } => out.elems() * CPU_ELEM,
        OpKind::TileRows { .. } => out.elems() * CPU_ELEM,
    };
    base + CPU_KERNEL_OVERHEAD
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::Graph;

    #[test]
    fn conv_dominates_fig6a_baseline() {
        // The Fig. 8 story requires conv ~99% of CPU time on the
        // Fig. 6a net.
        let mut g = Graph::new("fig6a-ish");
        let x = g.add_input("x", &[1, 32, 32, 16], 1);
        let c = g.conv2d("conv", x, 16, 3, 3, 1, 1, true, 8, 2).unwrap();
        let p = g.maxpool2d("pool", c, 8, 8).unwrap();
        let d = g.dense("fc", p, 8, false, 0, true, 3).unwrap();
        g.mark_output(d);
        let cycles: Vec<u64> = g.nodes.iter().map(|n| cpu_cycles(&g, n)).collect();
        let total: u64 = cycles.iter().sum();
        assert!(cycles[0] as f64 / total as f64 > 0.98, "conv share {:?}", cycles);
        assert!(cycles[1] > cycles[2], "pool should outweigh fc: {cycles:?}");
    }
}
