//! RV32I software cost model — cycle estimates for workload sections
//! that fall back to a management core (the paper's CPU path).
//!
//! Constants live in [`crate::energy::calib`] with their anchors.

use crate::config::{AccelKind, ClusterConfig};

use crate::energy::calib::*;

use super::ir::{Graph, Node, OpKind};

/// Cycles for node `n` executed in software on a management core.
pub fn cpu_cycles(g: &Graph, n: &Node) -> u64 {
    let out = g.tensor(n.output);
    let base = match n.kind {
        OpKind::Conv2d { kh, kw, .. } => {
            let wd = g.tensor(n.inputs[1]);
            let cin = (wd.dims[0] / (kh * kw)) as u64;
            out.elems() * kh as u64 * kw as u64 * cin * CPU_MAC_CONV
        }
        OpKind::Dense { .. } => {
            let wd = g.tensor(n.inputs[1]);
            out.elems() * wd.dims[0] as u64 * CPU_MAC_FC
        }
        OpKind::MaxPool2d { k, .. } => out.elems() * (k as u64 * k as u64) * CPU_POOL_OP,
        OpKind::GlobalAvgPool => {
            let xd = g.tensor(n.inputs[0]);
            xd.elems() * CPU_AVG
        }
        OpKind::ResidualAdd { .. } => out.elems() * CPU_ELEM,
        OpKind::TileRows { .. } => out.elems() * CPU_ELEM,
    };
    base + CPU_KERNEL_OVERHEAD
}

/// Estimated cycles for node `n` on cluster `cfg`, accounting for the
/// accelerators it carries: GeMM-shaped ops collapse to one 8x8x8 PE
/// step per 512 MACs when a GeMM unit exists, pooling to 8-lane steps,
/// everything else (or any cluster without a matching unit) falls back
/// to [`cpu_cycles`]. This is the partition pass's balance metric —
/// the same figure of merit the placement pass optimizes, evaluated
/// per candidate cluster.
pub fn node_cost(g: &Graph, n: &Node, cfg: &ClusterConfig) -> u64 {
    let out = g.tensor(n.output);
    match n.kind {
        OpKind::Conv2d { kh, kw, .. } if cfg.find_accel(AccelKind::Gemm).is_some() => {
            let wd = g.tensor(n.inputs[1]);
            let cin = (wd.dims[0] / (kh * kw)) as u64;
            let macs = out.elems() * kh as u64 * kw as u64 * cin;
            macs.div_ceil(512) + CPU_KERNEL_OVERHEAD
        }
        OpKind::Dense { .. } if cfg.find_accel(AccelKind::Gemm).is_some() => {
            let wd = g.tensor(n.inputs[1]);
            let macs = out.elems() * wd.dims[0] as u64;
            macs.div_ceil(512) + CPU_KERNEL_OVERHEAD
        }
        OpKind::MaxPool2d { k, .. } if cfg.find_accel(AccelKind::MaxPool).is_some() => {
            // k*k window reads per output element, 8 lanes wide (same
            // window-area accounting as the CPU model).
            (out.elems() * (k as u64 * k as u64)).div_ceil(8) + CPU_KERNEL_OVERHEAD
        }
        OpKind::ResidualAdd { .. } if cfg.find_accel(AccelKind::VecAdd).is_some() => {
            out.elems().div_ceil(8) + CPU_KERNEL_OVERHEAD
        }
        _ => cpu_cycles(g, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::Graph;

    #[test]
    fn conv_dominates_fig6a_baseline() {
        // The Fig. 8 story requires conv ~99% of CPU time on the
        // Fig. 6a net.
        let mut g = Graph::new("fig6a-ish");
        let x = g.add_input("x", &[1, 32, 32, 16], 1);
        let c = g.conv2d("conv", x, 16, 3, 3, 1, 1, true, 8, 2).unwrap();
        let p = g.maxpool2d("pool", c, 8, 8).unwrap();
        let d = g.dense("fc", p, 8, false, 0, true, 3).unwrap();
        g.mark_output(d);
        let cycles: Vec<u64> = g.nodes.iter().map(|n| cpu_cycles(&g, n)).collect();
        let total: u64 = cycles.iter().sum();
        assert!(cycles[0] as f64 / total as f64 > 0.98, "conv share {:?}", cycles);
        assert!(cycles[1] > cycles[2], "pool should outweigh fc: {cycles:?}");
    }

    #[test]
    fn accel_aware_cost_reflects_cluster_capabilities() {
        let mut g = Graph::new("t");
        let x = g.add_input("x", &[1, 16, 16, 8], 1);
        let c = g.conv2d("conv", x, 8, 3, 3, 1, 1, true, 8, 2).unwrap();
        let p = g.maxpool2d("pool", c, 2, 2).unwrap();
        g.mark_output(p);
        let b = crate::config::ClusterConfig::fig6b();
        let d = crate::config::ClusterConfig::fig6d();
        for n in &g.nodes {
            // fig6b has no accelerators: node_cost == cpu_cycles.
            assert_eq!(node_cost(&g, n, &b), cpu_cycles(&g, n), "{}", n.name);
            // fig6d accelerates both ops: much cheaper than the CPU.
            assert!(node_cost(&g, n, &d) < cpu_cycles(&g, n) / 4, "{}", n.name);
        }
    }
}
