//! Static memory allocation (SNAX-MLIR pass 2, paper Fig. 5.2).
//!
//! Buffers are placed in the shared scratchpad so producer-consumer
//! chains never round-trip through external memory:
//!
//! * **Activations** get liveness-based first-fit placement; in
//!   pipelined mode every inter-stage tensor is double-buffered
//!   (odd/even pipeline iterations — paper: "separate buffers designated
//!   for reading and writing during alternating odd and even pipeline
//!   cycles").
//! * **Weights** stay resident when everything fits; otherwise they are
//!   streamed from external memory into one or two rotating weight
//!   slots (two slots = next layer's weights DMA-prefetched during the
//!   current layer's compute — the paper's DMA/compute overlap).

use anyhow::{bail, Result};

use crate::config::ClusterConfig;

use super::ir::{Graph, TensorId, TensorKind};

const ALIGN: u64 = 64;

fn align(v: u64) -> u64 {
    v.div_ceil(ALIGN) * ALIGN
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WeightMode {
    /// All weights live in SPM for the whole run.
    Resident,
    /// Weights are DMA'd per layer into rotating slots.
    Streamed { slots: Vec<u64>, slot_bytes: u64 },
}

#[derive(Debug, Clone)]
pub struct AllocMap {
    /// Per tensor: SPM base address for even/odd pipeline iterations
    /// (equal when not double-buffered). `None` for streamed weights.
    pub spm_addr: Vec<Option<[u64; 2]>>,
    pub weight_mode: WeightMode,
    /// Per tensor: external-memory address (inputs, weights, outputs).
    pub ext_addr: Vec<Option<u64>>,
    /// Per tensor: true when the ext address was **pinned** by the
    /// system partition pass to another part's output region (a
    /// cross-cluster handoff). Pinned tensors get no `ext_mem_init`
    /// bytes (the producing part writes them at runtime) and their
    /// input DMA reads the per-inference region the producer wrote.
    pub ext_pinned: Vec<bool>,
    pub spm_used: u64,
    /// End of this allocation's ext cursor (absolute — includes the
    /// `ext_base` the partition pass assigned to this part).
    pub ext_used: u64,
    /// Whether activations are double-buffered (pipelined mode).
    pub double_buffered: bool,
}

impl AllocMap {
    pub fn spm(&self, t: TensorId, iter: u64) -> u64 {
        self.spm_addr[t.0].expect("tensor has SPM address")[(iter % 2) as usize]
    }

    pub fn ext(&self, t: TensorId) -> u64 {
        self.ext_addr[t.0].expect("tensor has ext address")
    }

    /// Was `t`'s ext address pinned to another part's region by the
    /// system partition pass?
    pub fn pinned(&self, t: TensorId) -> bool {
        self.ext_pinned[t.0]
    }

    /// SPM address of node `i`'s weights (resident or its rotating slot).
    pub fn weight_spm(&self, t: TensorId, node_idx: usize) -> u64 {
        match &self.weight_mode {
            WeightMode::Resident => self.spm(t, 0),
            WeightMode::Streamed { slots, .. } => slots[node_idx % slots.len()],
        }
    }
}

/// Liveness interval of each tensor over the node order.
fn liveness(g: &Graph) -> Vec<(i64, i64)> {
    let n = g.nodes.len() as i64;
    g.tensors
        .iter()
        .enumerate()
        .map(|(ti, t)| {
            let tid = TensorId(ti);
            let start = match t.kind {
                TensorKind::Input { .. } | TensorKind::Weight { .. } => -1,
                _ => g.producer(tid).map(|p| p.0 as i64).unwrap_or(-1),
            };
            let mut end = match t.kind {
                TensorKind::Output => n,
                _ => start,
            };
            for (ni, node) in g.nodes.iter().enumerate() {
                if node.inputs.contains(&tid) {
                    end = end.max(ni as i64);
                }
            }
            (start, end)
        })
        .collect()
}

/// First-fit placement of intervals: each candidate goes at the lowest
/// address not overlapping any live-range-intersecting placed tensor.
struct Placer {
    placed: Vec<(u64, u64, i64, i64)>, // (addr, bytes, live_start, live_end)
    capacity: u64,
    high_water: u64,
}

impl Placer {
    fn new(capacity: u64) -> Self {
        Self { placed: Vec::new(), capacity, high_water: 0 }
    }

    fn place(&mut self, bytes: u64, live: (i64, i64)) -> Result<u64> {
        let bytes = align(bytes.max(1));
        let mut addr = 0u64;
        loop {
            let conflict = self.placed.iter().find(|&&(a, b, s, e)| {
                let overlaps_addr = addr < a + b && a < addr + bytes;
                let overlaps_live = live.0 <= e && s <= live.1;
                overlaps_addr && overlaps_live
            });
            match conflict {
                Some(&(a, b, _, _)) => addr = align(a + b),
                None => break,
            }
            if addr + bytes > self.capacity {
                bail!(
                    "scratchpad overflow: need {} bytes at {addr}, capacity {}",
                    bytes,
                    self.capacity
                );
            }
        }
        if addr + bytes > self.capacity {
            bail!("scratchpad overflow: {} bytes do not fit in {}", bytes, self.capacity);
        }
        self.placed.push((addr, bytes, live.0, live.1));
        self.high_water = self.high_water.max(addr + bytes);
        Ok(addr)
    }
}

pub fn allocate(
    g: &Graph,
    cfg: &ClusterConfig,
    double_buffer_activations: bool,
) -> Result<AllocMap> {
    allocate_with_slots(g, cfg, double_buffer_activations, 2)
}

/// Like [`allocate`], with a cap on rotating weight slots (1 disables
/// the DMA-prefetch overlap — the ablation knob).
pub fn allocate_with_slots(
    g: &Graph,
    cfg: &ClusterConfig,
    double_buffer_activations: bool,
    max_weight_slots: usize,
) -> Result<AllocMap> {
    allocate_system(g, cfg, double_buffer_activations, max_weight_slots, 0, &[], 1)
}

/// The full allocator, as driven by the system partition pass: this
/// part's external-memory layout starts at `ext_base` (parts of one
/// system occupy disjoint regions of the shared memory), `ext_pins`
/// force specific tensors onto absolute addresses inside *another*
/// part's region — the producer-side output buffers of cross-cluster
/// handoffs — and each output tensor reserves `out_rooms` per-inference
/// regions (the `addr + inf * pitch` family the output store writes),
/// so a part publishing several handoff tensors cannot alias inference
/// `i+1` of one onto inference `i` of the next. The single-cluster path
/// passes `out_rooms = 1` — its one output historically spills past the
/// cursor into untracked memory, which is harmless with nothing
/// allocated behind it and kept for layout stability.
#[allow(clippy::too_many_arguments)]
pub fn allocate_system(
    g: &Graph,
    cfg: &ClusterConfig,
    double_buffer_activations: bool,
    max_weight_slots: usize,
    ext_base: u64,
    ext_pins: &[(TensorId, u64)],
    out_rooms: u32,
) -> Result<AllocMap> {
    let capacity = cfg.spm_bytes();
    let live = liveness(g);
    let nt = g.tensors.len();

    let weight_ids: Vec<TensorId> = (0..nt)
        .map(TensorId)
        .filter(|&t| matches!(g.tensor(t).kind, TensorKind::Weight { .. }))
        .collect();
    let act_ids: Vec<TensorId> = (0..nt)
        .map(TensorId)
        .filter(|&t| !matches!(g.tensor(t).kind, TensorKind::Weight { .. }))
        .collect();

    let weight_total: u64 = weight_ids.iter().map(|&t| align(g.tensor(t).bytes())).sum();
    let max_weight: u64 = weight_ids.iter().map(|&t| align(g.tensor(t).bytes())).max().unwrap_or(0);
    // Peak activation demand. Pipelined: everything coexists (x2).
    // Sequential: the maximum over node steps of concurrently-live
    // activation bytes.
    let act_total: u64 = if double_buffer_activations {
        act_ids.iter().map(|&t| align(g.tensor(t).bytes())).sum::<u64>() * 2
    } else {
        (-1..=g.nodes.len() as i64)
            .map(|step| {
                act_ids
                    .iter()
                    .filter(|&&t| {
                        let (s, e) = live[t.0];
                        s <= step && step <= e
                    })
                    .map(|&t| align(g.tensor(t).bytes()))
                    .sum::<u64>()
            })
            .max()
            .unwrap_or(0)
    };

    // Candidate weight modes in preference order; first-fit
    // fragmentation can defeat the arithmetic check, so each candidate
    // attempts a *full* placement and falls through on overflow.
    let mut candidates: Vec<(usize, bool)> = Vec::new(); // (slots, resident)
    if weight_total + act_total <= capacity {
        candidates.push((0, true));
    }
    if max_weight > 0 {
        if max_weight_slots >= 2 && max_weight * 2 + act_total <= capacity {
            candidates.push((2, false));
        }
        candidates.push((1, false));
    } else if candidates.is_empty() {
        candidates.push((0, true));
    }

    let mut last_err = None;
    let mut placed: Option<(Vec<Option<[u64; 2]>>, WeightMode, Placer)> = None;
    for (n_slots, resident) in candidates {
        let attempt = || -> Result<(Vec<Option<[u64; 2]>>, WeightMode, Placer)> {
            let mut spm_addr: Vec<Option<[u64; 2]>> = vec![None; nt];
            let mut placer = Placer::new(capacity);
            let whole = (-1i64, g.nodes.len() as i64);
            // Weights first (whole-run lifetime keeps them clear of reuse).
            let mode = if resident {
                for &t in &weight_ids {
                    let a = placer.place(g.tensor(t).bytes(), whole)?;
                    spm_addr[t.0] = Some([a, a]);
                }
                WeightMode::Resident
            } else {
                let mut slots = Vec::new();
                for _ in 0..n_slots {
                    slots.push(placer.place(max_weight, whole)?);
                }
                WeightMode::Streamed { slots, slot_bytes: max_weight }
            };
            // Activations.
            for &t in &act_ids {
                let bytes = g.tensor(t).bytes();
                if double_buffer_activations {
                    // Double buffers coexist across the whole pipeline.
                    let a0 = placer.place(bytes, whole)?;
                    let a1 = placer.place(bytes, whole)?;
                    spm_addr[t.0] = Some([a0, a1]);
                } else {
                    let a = placer.place(bytes, live[t.0])?;
                    spm_addr[t.0] = Some([a, a]);
                }
            }
            Ok((spm_addr, mode, placer))
        };
        match attempt() {
            Ok(ok) => {
                placed = Some(ok);
                break;
            }
            Err(e) => last_err = Some(e),
        }
    }
    let Some((spm_addr, weight_mode, placer)) = placed else {
        bail!(
            "workload does not fit: weights max {max_weight}B (total {weight_total}B), \
             peak activations {act_total}B, SPM {capacity}B — needs finer tiling than \
             this compiler performs ({})",
            last_err.map(|e| e.to_string()).unwrap_or_default()
        );
    };

    // External memory layout: inputs, then weights, then output region
    // — all offset by this part's base. Pinned tensors live in another
    // part's region instead and consume no local cursor space.
    let mut ext_addr: Vec<Option<u64>> = vec![None; nt];
    let mut ext_pinned: Vec<bool> = vec![false; nt];
    for &(t, addr) in ext_pins {
        ext_addr[t.0] = Some(addr);
        ext_pinned[t.0] = true;
    }
    let mut ext_cursor = ext_base;
    for ti in 0..nt {
        if ext_pinned[ti] {
            continue;
        }
        let t = g.tensor(TensorId(ti));
        match t.kind {
            TensorKind::Input { .. } | TensorKind::Weight { .. } => {
                ext_addr[ti] = Some(ext_cursor);
                ext_cursor += align(t.bytes());
            }
            TensorKind::Output => {
                ext_addr[ti] = Some(ext_cursor);
                ext_cursor += align(t.bytes()) * out_rooms.max(1) as u64;
            }
            TensorKind::Intermediate => {}
        }
    }

    Ok(AllocMap {
        spm_addr,
        weight_mode,
        ext_addr,
        ext_pinned,
        spm_used: placer.high_water,
        ext_used: ext_cursor,
        double_buffered: double_buffer_activations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::ir::Graph;
    use crate::config::ClusterConfig;

    fn small_graph() -> Graph {
        let mut g = Graph::new("t");
        let x = g.add_input("x", &[1, 16, 16, 8], 1);
        let c = g.conv2d("conv", x, 8, 3, 3, 1, 1, true, 8, 2).unwrap();
        let p = g.maxpool2d("pool", c, 2, 2).unwrap();
        let d = g.dense("fc", p, 8, false, 0, true, 3).unwrap();
        g.mark_output(d);
        g
    }

    fn no_overlap(g: &Graph, m: &AllocMap) {
        // Any two tensors with SPM addresses and intersecting liveness
        // must not overlap in address range.
        let live = liveness(g);
        for i in 0..g.tensors.len() {
            for j in (i + 1)..g.tensors.len() {
                let (Some(ai), Some(aj)) = (m.spm_addr[i], m.spm_addr[j]) else { continue };
                let li = live[i];
                let lj = live[j];
                let live_overlap = m.double_buffered || (li.0 <= lj.1 && lj.0 <= li.1);
                if !live_overlap {
                    continue;
                }
                let (bi, bj) = (g.tensors[i].bytes(), g.tensors[j].bytes());
                for a in ai {
                    for b in aj {
                        assert!(
                            a + bi <= b || b + bj <= a,
                            "tensors {i} and {j} overlap: {a}+{bi} vs {b}+{bj}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn resident_weights_when_fit() {
        let g = small_graph();
        let m = allocate(&g, &ClusterConfig::fig6d(), false).unwrap();
        assert_eq!(m.weight_mode, WeightMode::Resident);
        assert!(m.spm_used <= ClusterConfig::fig6d().spm_bytes());
        no_overlap(&g, &m);
    }

    #[test]
    fn double_buffering_doubles_activation_footprint() {
        let g = small_graph();
        let single = allocate(&g, &ClusterConfig::fig6d(), false).unwrap();
        let double = allocate(&g, &ClusterConfig::fig6d(), true).unwrap();
        assert!(double.spm_used > single.spm_used);
        no_overlap(&g, &double);
        // Odd/even buffers must differ.
        let out = g.outputs()[0];
        let [a0, a1] = double.spm_addr[out.0].unwrap();
        assert_ne!(a0, a1);
    }

    #[test]
    fn streams_weights_when_too_big() {
        // DAE-like stack: 640x128 + 8x 128x128 + 128x640 weights
        // (~260KB) >> 128KB SPM, but the largest layer (80KB) fits.
        let mut g = Graph::new("big");
        let mut x = g.add_input("x", &[8, 640], 1);
        x = g.dense("fc0", x, 128, true, 9, false, 100).unwrap();
        for i in 1..9 {
            x = g.dense(&format!("fc{i}"), x, 128, true, 8, false, 100 + i).unwrap();
        }
        x = g.dense("fc9", x, 640, false, 0, true, 109).unwrap();
        g.mark_output(x);
        let m = allocate(&g, &ClusterConfig::fig6d(), false).unwrap();
        match &m.weight_mode {
            WeightMode::Streamed { slots, slot_bytes } => {
                assert!(!slots.is_empty());
                assert!(*slot_bytes >= 640 * 128);
            }
            other => panic!("expected streamed weights, got {other:?}"),
        }
        no_overlap(&g, &m);
    }

    #[test]
    fn impossible_workload_rejected() {
        let mut g = Graph::new("huge");
        // One activation bigger than the whole SPM.
        let x = g.add_input("x", &[1, 1024, 1024, 16], 1);
        let c = g.conv2d("conv", x, 16, 3, 3, 1, 1, true, 8, 2).unwrap();
        g.mark_output(c);
        assert!(allocate(&g, &ClusterConfig::fig6d(), false).is_err());
    }

    #[test]
    fn ext_base_and_pins_relocate_the_layout() {
        let g = small_graph();
        let cfg = ClusterConfig::fig6d();
        let base = allocate(&g, &cfg, false).unwrap();
        let input = g.inputs()[0];
        let moved =
            allocate_system(&g, &cfg, false, 2, 1 << 20, &[(input, 0x440)], 1).unwrap();
        // Pinned input sits at the foreign address, untouched by the
        // cursor; everything else shifted by the base.
        assert!(moved.pinned(input));
        assert_eq!(moved.ext(input), 0x440);
        for (ti, t) in g.tensors.iter().enumerate() {
            if ti == input.0 || base.ext_addr[ti].is_none() {
                continue;
            }
            assert!(moved.ext_addr[ti].unwrap() >= 1 << 20, "{}", t.name);
            assert!(!moved.ext_pinned[ti]);
        }
        assert!(moved.ext_used >= 1 << 20);
        // SPM layout is unaffected by the external relocation.
        assert_eq!(moved.spm_addr, base.spm_addr);
    }

    #[test]
    fn ext_layout_covers_io_and_weights() {
        let g = small_graph();
        let m = allocate(&g, &ClusterConfig::fig6d(), false).unwrap();
        for (ti, t) in g.tensors.iter().enumerate() {
            match t.kind {
                TensorKind::Intermediate => assert!(m.ext_addr[ti].is_none()),
                _ => assert!(m.ext_addr[ti].is_some(), "{}", t.name),
            }
        }
        assert!(m.ext_used > 0);
    }
}
