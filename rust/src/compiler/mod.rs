//! SNAX-MLIR analogue — the automated compiler passes of paper Fig. 5
//! over the tensor IR, grown by one SoC-level pass ahead of them:
//!
//! 0. [`partition`] — cross-cluster partitioning (pipeline stages or
//!    data-parallel shards across a [`crate::config::SystemConfig`])
//! 1. [`placement`] — device placement
//! 2. [`alloc`] — static scratchpad allocation (+ double buffering)
//! 3. + 4. [`codegen`] — asynchronous scheduling (pipeline unrolling,
//!    barrier insertion) and device programming (CSR compute kernels +
//!    streamer dataflow kernels)
//!
//! [`compile`] chains passes 1-4 for one cluster and returns a
//! [`CompiledProgram`] ready for [`crate::sim::Cluster::run`];
//! [`compile_system`] runs pass 0 then compiles every part, returning
//! a [`CompiledSystem`] for [`crate::sim::System::run`].

pub mod alloc;
pub mod codegen;
pub mod cost;
pub mod fingerprint;
pub mod ir;
pub mod partition;
pub mod placement;

use anyhow::{Context, Result};

use crate::config::ClusterConfig;
use crate::isa::Program;
use crate::sim::SimReport;

pub use codegen::Mode;
pub use fingerprint::{program_key, system_key, Fnv1a};
pub use ir::{Graph, NodeId, TensorId};
pub use partition::{compile_system, CompiledSystem, PartitionPlan, PartitionStrategy};
pub use placement::{Device, Placement, PlacementOverrides};

/// A compiled program shared across threads (the `snax serve` cache
/// hands the same compilation to many concurrent simulations; all
/// [`CompiledProgram`] fields are immutable after [`compile`]).
pub type SharedProgram = std::sync::Arc<CompiledProgram>;

/// Compilation options (the paper's "explicit configuration flags and
/// target descriptions provided during compilation").
#[derive(Debug, Clone)]
pub struct CompileOptions {
    pub mode: Mode,
    /// Back-to-back inferences to emit (pipelined throughput needs >1).
    pub n_inferences: u32,
    pub overrides: PlacementOverrides,
    /// Rotating weight slots for streamed weights (2 = DMA prefetch
    /// overlap, 1 = strictly serialized loads; ablation knob).
    pub max_weight_slots: usize,
}

impl CompileOptions {
    pub fn sequential() -> Self {
        Self {
            mode: Mode::Sequential,
            n_inferences: 1,
            overrides: Default::default(),
            max_weight_slots: 2,
        }
    }

    pub fn pipelined() -> Self {
        Self {
            mode: Mode::Pipelined,
            n_inferences: 8,
            overrides: Default::default(),
            max_weight_slots: 2,
        }
    }

    pub fn single_weight_slot(mut self) -> Self {
        self.max_weight_slots = 1;
        self
    }

    pub fn with_inferences(mut self, n: u32) -> Self {
        self.n_inferences = n;
        self
    }

    pub fn force_cpu(mut self, names: &[&str]) -> Self {
        self.overrides.force_cpu = names.iter().map(|s| s.to_string()).collect();
        self
    }
}

/// A compiled workload plus the layout metadata needed to read results.
pub struct CompiledProgram {
    pub program: Program,
    pub placement: Placement,
    pub alloc: alloc::AllocMap,
    pub graph: Graph,
    pub options: CompileOptions,
}

impl CompiledProgram {
    /// Read the bytes of output tensor `idx` for inference `inf` from a
    /// finished run's external memory.
    pub fn read_output(&self, report: &SimReport, idx: usize, inf: u64) -> Vec<u8> {
        let t = self.graph.outputs()[idx];
        let bytes = self.graph.tensor(t).bytes();
        let addr = self.alloc.ext(t) + inf * bytes.div_ceil(64) * 64;
        report.read_ext(addr, bytes as usize).to_vec()
    }

    pub fn n_inferences(&self) -> u32 {
        self.options.n_inferences
    }
}

/// Run the full pass pipeline.
pub fn compile(
    graph: &Graph,
    cfg: &ClusterConfig,
    options: &CompileOptions,
) -> Result<CompiledProgram> {
    graph.validate().with_context(|| format!("validating graph '{}'", graph.name))?;
    cfg.validate()?;
    let placement = placement::place(graph, cfg, &options.overrides);
    let double_buffer = options.mode == Mode::Pipelined;
    let alloc = alloc::allocate_with_slots(graph, cfg, double_buffer, options.max_weight_slots)
        .with_context(|| format!("allocating '{}' on '{}'", graph.name, cfg.name))?;
    let program = codegen::generate(&codegen::CodegenInput {
        graph,
        cfg,
        placement: &placement,
        alloc: &alloc,
        mode: options.mode,
        n_inferences: options.n_inferences,
        sync: None,
    })
    .with_context(|| format!("generating code for '{}'", graph.name))?;
    Ok(CompiledProgram {
        program,
        placement,
        alloc,
        graph: graph.clone(),
        options: options.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g.add_input("x", &[1, 16, 16, 8], 10);
        let c = g.conv2d("conv", x, 8, 3, 3, 1, 1, true, 8, 11).unwrap();
        let p = g.maxpool2d("pool", c, 2, 2).unwrap();
        let t = g.tile_rows("tile", p, 8).unwrap();
        let d = g.dense("fc", t, 8, false, 0, true, 12).unwrap();
        g.mark_output(d);
        g
    }

    #[test]
    fn compiles_sequential_on_all_presets() {
        for preset in ["fig6b", "fig6c", "fig6d"] {
            let cfg = ClusterConfig::preset(preset).unwrap();
            let cp = compile(&tiny(), &cfg, &CompileOptions::sequential()).unwrap();
            assert_eq!(cp.program.streams.len(), cfg.cores.len());
            assert!(cp.program.n_instrs() > 0);
        }
    }

    #[test]
    fn compiles_pipelined_on_fig6d() {
        let cfg = ClusterConfig::fig6d();
        let cp = compile(&tiny(), &cfg, &CompileOptions::pipelined()).unwrap();
        assert!(cp.alloc.double_buffered);
        // Pipelined emits more instructions (unrolled ticks).
        let seq = compile(&tiny(), &cfg, &CompileOptions::sequential()).unwrap();
        assert!(cp.program.n_instrs() > seq.program.n_instrs());
    }

    #[test]
    fn ext_image_contains_inputs_and_weights() {
        let cfg = ClusterConfig::fig6d();
        let cp = compile(&tiny(), &cfg, &CompileOptions::sequential()).unwrap();
        // input + conv.w + fc.w
        assert_eq!(cp.program.ext_mem_init.len(), 3);
        let total: usize = cp.program.ext_mem_init.iter().map(|(_, b)| b.len()).sum();
        // input + conv.w [72,8] + fc.w [512,8]
        assert_eq!(total as u64, (16 * 16 * 8) + (72 * 8) + (512 * 8));
    }

    #[test]
    fn layer_names_cover_nodes_and_dma() {
        let cfg = ClusterConfig::fig6d();
        let cp = compile(&tiny(), &cfg, &CompileOptions::sequential()).unwrap();
        assert_eq!(
            cp.program.layer_names,
            vec!["conv", "pool", "tile", "fc", "dma_in", "dma_out"]
        );
    }
}
