//! Plain-text table/series rendering shared by the benches and CLI —
//! every paper figure/table is regenerated as one of these.

use std::fmt::Write;

/// Render an aligned ASCII table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, cell) in r.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate().take(ncol) {
            let _ = write!(s, " {:<w$} |", c, w = widths[i]);
        }
        out.push_str(&s);
        out.push('\n');
    };
    line(&mut out, &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    let mut sep = String::from("|");
    for w in &widths {
        let _ = write!(sep, "{}|", "-".repeat(w + 2));
    }
    out.push_str(&sep);
    out.push('\n');
    for r in rows {
        line(&mut out, r);
    }
    out
}

/// Format a cycle count with thousands separators.
pub fn cycles(v: u64) -> String {
    let s = v.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Format a ratio like "152.3x".
pub fn ratio(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format a percentage.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = table(
            &["name", "cycles"],
            &[
                vec!["conv".into(), "123".into()],
                vec!["maxpool".into(), "4".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].contains("conv"));
        // All rows same width.
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(cycles(1234567), "1,234,567");
        assert_eq!(cycles(42), "42");
        assert_eq!(ratio(152.34), "152.34x");
        assert_eq!(pct(0.923), "92.3%");
    }
}
