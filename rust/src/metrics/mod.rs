//! Metrics and reporting: roofline analysis (Fig. 10) and the
//! table/series formatting shared by the benches and the CLI.

pub mod report;
pub mod roofline;

pub use roofline::{roofline_bound, RooflinePoint};
