//! Roofline analysis (paper Fig. 10, after Williams et al. [26]).
//!
//! Peak compute = the GeMM array's 512 MACs/cycle = 1024 int8 ops/cycle;
//! the memory roof is the AXI bandwidth (64 B/cycle at 512 bits). The
//! ridge point sits at `peak_ops / bw` ops/byte; the paper reports 92%
//! of peak at high intensity, 79% of bandwidth at low intensity, and
//! 78% at the ridge for SNAX.

use crate::config::ClusterConfig;
use crate::models::matmul::MatmulWorkload;
use crate::sim::SimReport;

/// Ops per cycle at peak (1 MAC = 2 ops).
pub fn peak_ops_per_cycle(_cfg: &ClusterConfig) -> f64 {
    2.0 * crate::sim::accel::gemm::MACS_PER_CYCLE as f64
}

/// AXI bytes per cycle.
pub fn axi_bytes_per_cycle(cfg: &ClusterConfig) -> f64 {
    cfg.axi_bits as f64 / 8.0
}

/// The roofline bound (ops/cycle) at arithmetic intensity `ai`.
pub fn roofline_bound(cfg: &ClusterConfig, ai: f64) -> f64 {
    let peak = peak_ops_per_cycle(cfg);
    let mem = ai * axi_bytes_per_cycle(cfg);
    peak.min(mem)
}

/// Intensity of the ridge point (ops/byte).
pub fn ridge_intensity(cfg: &ClusterConfig) -> f64 {
    peak_ops_per_cycle(cfg) / axi_bytes_per_cycle(cfg)
}

/// One measured point of the Fig. 10 sweep.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub tile: u64,
    pub intensity: f64,
    /// Achieved ops/cycle over the whole run.
    pub achieved: f64,
    /// Roofline bound at this intensity.
    pub bound: f64,
}

impl RooflinePoint {
    /// Fraction of the roofline achieved (the paper's utilization).
    pub fn utilization(&self) -> f64 {
        if self.bound == 0.0 {
            0.0
        } else {
            self.achieved / self.bound
        }
    }

    pub fn from_run(cfg: &ClusterConfig, w: &MatmulWorkload, report: &SimReport) -> Self {
        let ai = w.intensity();
        let achieved = w.total_ops() as f64 / report.total_cycles.max(1) as f64;
        Self {
            tile: w.m,
            intensity: ai,
            achieved,
            bound: roofline_bound(cfg, ai),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ridge_at_16_ops_per_byte() {
        // 1024 ops/cycle over 64 B/cycle.
        let cfg = ClusterConfig::fig6c();
        assert!((ridge_intensity(&cfg) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn bound_is_min_of_roofs() {
        let cfg = ClusterConfig::fig6c();
        assert!((roofline_bound(&cfg, 1.0) - 64.0).abs() < 1e-9); // memory
        assert!((roofline_bound(&cfg, 100.0) - 1024.0).abs() < 1e-9); // compute
    }

    #[test]
    fn utilization_of_perfect_point_is_one() {
        let p = RooflinePoint { tile: 64, intensity: 32.0, achieved: 1024.0, bound: 1024.0 };
        assert!((p.utilization() - 1.0).abs() < 1e-12);
    }
}
