//! Conventional-integration baselines the paper compares against.
//!
//! * **Fig. 8 baseline**: the whole network on the RV32I core — obtained
//!   by compiling for the accelerator-less `fig6b` preset (placement
//!   falls back to CPU for every node), or by `force_cpu` overrides.
//! * **Fig. 10 baseline**: the "C runtime library" [25] driving the same
//!   GeMM accelerator through blocking, serialized transfer/compute
//!   phases — [`crate::models::matmul::serialized_program`] — optionally
//!   with CSR double-buffering disabled ([`conventional_cluster`]),
//!   modeling a register interface without shadow banks.

use crate::config::ClusterConfig;

/// A cluster variant stripped of SNAX's hybrid-coupling niceties:
/// no double-buffered CSR shadow bank (configuration writes block while
/// the accelerator runs, as in a conventional memory-mapped interface).
pub fn conventional_cluster(cfg: &ClusterConfig) -> ClusterConfig {
    let mut c = cfg.clone();
    c.name = format!("{}-conventional", c.name);
    c.csr_double_buffer = false;
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::matmul::{overlapped_program, serialized_program, MatmulWorkload};
    use crate::sim::Cluster;

    #[test]
    fn conventional_flag_propagates() {
        let c = conventional_cluster(&ClusterConfig::fig6c());
        assert!(!c.csr_double_buffer);
        assert!(c.name.contains("conventional"));
        c.validate().unwrap();
    }

    #[test]
    fn snax_beats_conventional_on_the_same_accelerator() {
        // The Fig. 10 comparison: same GeMM, same workload, hybrid
        // coupling on vs off.
        let w = MatmulWorkload::square(64, 6);
        let snax_cfg = ClusterConfig::fig6c();
        let conv_cfg = conventional_cluster(&snax_cfg);
        let snax = Cluster::new(&snax_cfg)
            .run(&overlapped_program(&snax_cfg, w).unwrap())
            .unwrap();
        let conv = Cluster::new(&conv_cfg)
            .run(&serialized_program(&conv_cfg, w).unwrap())
            .unwrap();
        assert!(
            (snax.total_cycles as f64) < 0.8 * conv.total_cycles as f64,
            "snax {} vs conventional {}",
            snax.total_cycles,
            conv.total_cycles
        );
    }
}
