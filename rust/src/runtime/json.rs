//! Minimal JSON codec — no serde in this vendored environment.
//!
//! The parser originally existed for `artifacts/manifest.json`; the
//! `snax serve` service layer ([`crate::server`]) now uses it for every
//! request body and pairs it with the [`Value::to_json`] serializer for
//! responses. The grammar is full JSON (objects, arrays, strings with
//! `\uXXXX` escapes incl. surrogate pairs, numbers, booleans, null),
//! with trailing-garbage rejection at the top level.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Build an object from `(key, value)` pairs (server response
    /// convenience; `Obj` is a BTreeMap, so key order — and therefore
    /// the serialized byte stream — is deterministic).
    pub fn object<'a>(pairs: impl IntoIterator<Item = (&'a str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -- serialization ------------------------------------------------------

    /// Serialize to a compact JSON string. Integral floats print without
    /// a fraction part, non-finite floats degrade to `null` (JSON has no
    /// NaN/inf), and strings escape quotes, backslashes, and control
    /// characters.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    fn write_json(&self, out: &mut String) {
        use std::fmt::Write;
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_json_string(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_json(out);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write_json(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Num(v as f64)
    }
}

impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Num(v as f64)
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}

impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::Arr(v)
    }
}

fn write_json_string(s: &str, out: &mut String) {
    use std::fmt::Write;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing characters at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    skip_ws(b, pos);
    if *pos >= b.len() || b[*pos] != c {
        bail!("expected '{}' at byte {pos}", c as char);
    }
    *pos += 1;
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Value::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Value::Bool(true)),
        b'f' => lit(b, pos, "false", Value::Bool(false)),
        b'n' => lit(b, pos, "null", Value::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, s: &str, v: Value) -> Result<Value> {
    if b.len() - *pos >= s.len() && &b[*pos..*pos + s.len()] == s.as_bytes() {
        *pos += s.len();
        Ok(v)
    } else {
        bail!("bad literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Value> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    if *pos == start {
        bail!("expected a value at byte {start}");
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let n = s.parse::<f64>().with_context(|| format!("bad number '{s}' at byte {start}"))?;
    if !n.is_finite() {
        bail!("non-finite number '{s}' at byte {start}");
    }
    Ok(Value::Num(n))
}

/// Read exactly four hex digits (the payload of a `\u` escape).
fn hex4(b: &[u8], pos: &mut usize) -> Result<u32> {
    if *pos + 4 > b.len() {
        bail!("truncated \\u escape at byte {pos}");
    }
    let hex = &b[*pos..*pos + 4];
    if !hex.iter().all(|c| c.is_ascii_hexdigit()) {
        bail!("bad \\u escape at byte {pos}");
    }
    let v = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
    *pos += 4;
    Ok(v)
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("bad escape at end");
                }
                let esc = b[*pos];
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'u' => {
                        let cp = hex4(b, pos)?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: must pair with \uDC00..DFFF.
                            if *pos + 2 > b.len() || b[*pos] != b'\\' || b[*pos + 1] != b'u' {
                                bail!("unpaired high surrogate at byte {pos}");
                            }
                            *pos += 2;
                            let lo = hex4(b, pos)?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                bail!("invalid low surrogate at byte {pos}");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).context("surrogate pair out of range")?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            bail!("unpaired low surrogate at byte {pos}");
                        } else {
                            // Non-surrogate BMP scalar: always a valid char.
                            out.push(char::from_u32(cp).unwrap());
                        }
                    }
                    other => bail!("unknown escape \\{}", other as char),
                }
            }
            c => {
                // Raw UTF-8 passthrough.
                let ch_len = match c {
                    0..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                if *pos + ch_len > b.len() {
                    bail!("truncated UTF-8 sequence at byte {pos}");
                }
                out.push_str(std::str::from_utf8(&b[*pos..*pos + ch_len])?);
                *pos += ch_len;
            }
        }
    }
    bail!("unterminated string")
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Value> {
    expect(b, pos, b'[')?;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Value::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            bail!("unterminated array");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Value::Arr(out));
            }
            c => bail!("expected ',' or ']', got '{}'", c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Value> {
    expect(b, pos, b'{')?;
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Value::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        if *pos >= b.len() {
            bail!("unterminated object");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Value::Obj(out));
            }
            c => bail!("expected ',' or '}}', got '{}'", c as char),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let v = parse(
            r#"{"gemm_8x8x8": {"inputs": [{"shape": [8, 8], "dtype": "int8"}],
                "outputs": [{"shape": [8, 8], "dtype": "int32"}],
                "return_tuple": true, "sha256": "abc"}}"#,
        )
        .unwrap();
        let e = v.get("gemm_8x8x8").unwrap();
        assert_eq!(e.get("return_tuple").unwrap().as_bool(), Some(true));
        let ins = e.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].get("dtype").unwrap().as_str(), Some("int8"));
        let dims = ins[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(dims[0].as_u64(), Some(8));
    }

    #[test]
    fn parses_scalars_and_rejects_garbage() {
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse("\"a\\nb\"").unwrap().as_str(), Some("a\nb"));
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
        assert!(parse("[1,2] tail").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
        assert!(parse("--5").is_err());
        assert!(parse("@").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1,2],[3]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_u64(), Some(3));
    }

    #[test]
    fn unicode_escapes() {
        // BMP escape: \u0041 -> 'A'.
        assert_eq!(parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
        // Surrogate pair \ud83d\ude00 -> one astral scalar (U+1F600).
        assert_eq!(
            parse("\"\\ud83d\\ude00\"").unwrap().as_str(),
            Some("\u{1f600}")
        );
        // Unpaired surrogates are rejected, not replaced.
        assert!(parse(r#""\ud83d""#).is_err());
        assert!(parse(r#""\ud83dx""#).is_err());
        assert!(parse(r#""\ude00""#).is_err());
        // Truncated / non-hex escapes.
        assert!(parse(r#""\u00""#).is_err());
        assert!(parse(r#""\u00zz""#).is_err());
        // Raw UTF-8 passthrough still works.
        assert_eq!(parse("\"héllo\"").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn serializes_all_value_kinds() {
        let v = Value::object([
            ("b", Value::Bool(true)),
            ("n", Value::Num(42.0)),
            ("f", Value::Num(1.5)),
            ("s", Value::from("a\"b\\c\nd")),
            ("arr", Value::Arr(vec![Value::Null, Value::Num(-3.0)])),
            ("obj", Value::object([("k", Value::from("v"))])),
        ]);
        let j = v.to_json();
        assert_eq!(
            j,
            r#"{"arr":[null,-3],"b":true,"f":1.5,"n":42,"obj":{"k":"v"},"s":"a\"b\\c\nd"}"#
        );
    }

    #[test]
    fn serialize_parse_roundtrip() {
        let v = Value::object([
            ("nested", Value::Arr(vec![Value::object([("x", Value::Num(8.0))])])),
            ("text", Value::from("tab\there — ünïcode")),
            ("flag", Value::Bool(false)),
            ("nothing", Value::Null),
            ("ratio", Value::Num(0.921875)),
        ]);
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn control_chars_escape_as_u_sequences() {
        let j = Value::from("\u{1}bell\u{7}").to_json();
        assert_eq!(j, "\"\\u0001bell\\u0007\"");
        assert_eq!(parse(&j).unwrap().as_str(), Some("\u{1}bell\u{7}"));
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Value::Num(f64::NAN).to_json(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_json(), "null");
    }
}
