//! Minimal JSON parser — just enough for `artifacts/manifest.json`
//! (objects, arrays, strings, integers, booleans). This environment
//! vendors no serde_json; the grammar we consume is fixed and produced
//! by our own `aot.py`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing characters at byte {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    skip_ws(b, pos);
    if *pos >= b.len() || b[*pos] != c {
        bail!("expected '{}' at byte {pos}", c as char);
    }
    *pos += 1;
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Value::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Value::Bool(true)),
        b'f' => lit(b, pos, "false", Value::Bool(false)),
        b'n' => lit(b, pos, "null", Value::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, s: &str, v: Value) -> Result<Value> {
    if b.len() - *pos >= s.len() && &b[*pos..*pos + s.len()] == s.as_bytes() {
        *pos += s.len();
        Ok(v)
    } else {
        bail!("bad literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Value> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Value::Num(s.parse::<f64>()?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("bad escape at end");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'u' => {
                        // \uXXXX (BMP only — fine for our manifests).
                        if *pos + 4 >= b.len() {
                            bail!("bad unicode escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    other => bail!("unknown escape \\{}", other as char),
                }
                *pos += 1;
            }
            c => {
                // Raw UTF-8 passthrough.
                let ch_len = match c {
                    0..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                out.push_str(std::str::from_utf8(&b[*pos..*pos + ch_len])?);
                *pos += ch_len;
            }
        }
    }
    bail!("unterminated string")
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Value> {
    expect(b, pos, b'[')?;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Value::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if *pos >= b.len() {
            bail!("unterminated array");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Value::Arr(out));
            }
            c => bail!("expected ',' or ']', got '{}'", c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Value> {
    expect(b, pos, b'{')?;
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Value::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        out.insert(key, val);
        skip_ws(b, pos);
        if *pos >= b.len() {
            bail!("unterminated object");
        }
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Value::Obj(out));
            }
            c => bail!("expected ',' or '}}', got '{}'", c as char),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let v = parse(
            r#"{"gemm_8x8x8": {"inputs": [{"shape": [8, 8], "dtype": "int8"}],
                "outputs": [{"shape": [8, 8], "dtype": "int32"}],
                "return_tuple": true, "sha256": "abc"}}"#,
        )
        .unwrap();
        let e = v.get("gemm_8x8x8").unwrap();
        assert_eq!(e.get("return_tuple").unwrap().as_bool(), Some(true));
        let ins = e.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].get("dtype").unwrap().as_str(), Some("int8"));
        let dims = ins[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(dims[0].as_u64(), Some(8));
    }

    #[test]
    fn parses_scalars_and_rejects_garbage() {
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse("\"a\\nb\"").unwrap().as_str(), Some("a\nb"));
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1,2],[3]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_u64(), Some(3));
    }
}
