//! PJRT runtime — the AOT bridge from the build-time JAX/Pallas world
//! into the Rust request path.
//!
//! `make artifacts` (Python, build-time only) lowers every entry point
//! in `python/compile/model.py` to **HLO text** plus a JSON manifest of
//! input/output shapes. This module loads those artifacts, compiles
//! them on the PJRT CPU client, and executes them with int8/int32
//! tensors — no Python anywhere at run time.
//!
//! HLO *text* (not serialized `HloModuleProto`) is the interchange
//! format: jax >= 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and `python/compile/aot.py`).
//!
//! The XLA bindings are only available in environments that vendor the
//! `xla` crate, so everything touching it is gated behind the
//! off-by-default `pjrt` cargo feature. Enabling the feature requires
//! *also* adding the vendored crate to `rust/Cargo.toml` (e.g.
//! `xla = { path = "<vendored-xla>" }`) — it is deliberately not
//! declared there because it cannot be resolved offline. Non-`pjrt`
//! builds get the same [`ArtifactStore`] API as a stub whose
//! `open`/`execute` fail with a clear error, keeping every caller
//! compiling (and letting callers branch on [`PJRT_ENABLED`]).

pub mod json;

use anyhow::{bail, Result};

/// True when this binary was built with the `pjrt` feature (i.e.
/// [`ArtifactStore`] is real, not the offline stub).
pub const PJRT_ENABLED: bool = cfg!(feature = "pjrt");

/// Tensor dtype at the artifact boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I8,
    I32,
}

impl DType {
    pub fn from_manifest(s: &str) -> Result<Self> {
        match s {
            "int8" => Ok(DType::I8),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported artifact dtype '{other}'"),
        }
    }

    pub fn bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 => 4,
        }
    }
}

/// A host tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    /// Raw little-endian bytes, row-major.
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn from_i8(shape: &[usize], values: &[i8]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Self {
            dtype: DType::I8,
            shape: shape.to_vec(),
            data: values.iter().map(|&v| v as u8).collect(),
        }
    }

    pub fn from_bytes_i8(shape: &[usize], data: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { dtype: DType::I8, shape: shape.to_vec(), data }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_i8(&self) -> Vec<i8> {
        assert_eq!(self.dtype, DType::I8);
        self.data.iter().map(|&b| b as i8).collect()
    }

    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        self.data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

/// Shape/dtype signature of one artifact entry.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub name: String,
    pub inputs: Vec<(Vec<usize>, DType)>,
    pub outputs: Vec<(Vec<usize>, DType)>,
    pub sha256: String,
}

pub use store::ArtifactStore;

#[cfg(feature = "pjrt")]
mod store {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};

    use anyhow::{bail, Context, Result};

    use super::{json, DType, EntryMeta, Tensor};

    impl DType {
        fn element_type(self) -> xla::ElementType {
            match self {
                DType::I8 => xla::ElementType::S8,
                DType::I32 => xla::ElementType::S32,
            }
        }
    }

    impl Tensor {
        fn to_literal(&self) -> Result<xla::Literal> {
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                self.dtype.element_type(),
                &self.shape,
                &self.data,
            )?;
            Ok(lit)
        }

        fn from_literal(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<Self> {
            let data = match dtype {
                DType::I8 => lit.to_vec::<i8>()?.into_iter().map(|v| v as u8).collect(),
                DType::I32 => lit
                    .to_vec::<i32>()?
                    .into_iter()
                    .flat_map(|v| v.to_le_bytes())
                    .collect(),
            };
            Ok(Self { dtype, shape: shape.to_vec(), data })
        }
    }

    struct Entry {
        meta: EntryMeta,
        exe: xla::PjRtLoadedExecutable,
    }

    /// Loads `artifacts/` once, compiles each HLO module on the PJRT CPU
    /// client, and serves executions (lazily compiled on first use).
    pub struct ArtifactStore {
        dir: PathBuf,
        client: xla::PjRtClient,
        metas: BTreeMap<String, EntryMeta>,
        compiled: std::cell::RefCell<BTreeMap<String, std::rc::Rc<Entry>>>,
    }

    impl ArtifactStore {
        /// Open an artifact directory (reads `manifest.json`).
        pub fn open(dir: &Path) -> Result<Self> {
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path).with_context(|| {
                format!("reading {} — run `make artifacts`", manifest_path.display())
            })?;
            let root = json::parse(&text).context("parsing manifest.json")?;
            let obj = root.as_obj().context("manifest root must be an object")?;
            let mut metas = BTreeMap::new();
            for (name, entry) in obj {
                let sig = |key: &str| -> Result<Vec<(Vec<usize>, DType)>> {
                    entry
                        .get(key)
                        .and_then(|v| v.as_arr())
                        .with_context(|| format!("{name}: missing {key}"))?
                        .iter()
                        .map(|io| {
                            let shape = io
                                .get("shape")
                                .and_then(|v| v.as_arr())
                                .context("shape")?
                                .iter()
                                .map(|d| d.as_u64().map(|v| v as usize).context("dim"))
                                .collect::<Result<Vec<_>>>()?;
                            let dtype = DType::from_manifest(
                                io.get("dtype").and_then(|v| v.as_str()).context("dtype")?,
                            )?;
                            Ok((shape, dtype))
                        })
                        .collect()
                };
                metas.insert(
                    name.clone(),
                    EntryMeta {
                        name: name.clone(),
                        inputs: sig("inputs")?,
                        outputs: sig("outputs")?,
                        sha256: entry
                            .get("sha256")
                            .and_then(|v| v.as_str())
                            .unwrap_or_default()
                            .to_string(),
                    },
                );
            }
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { dir: dir.to_path_buf(), client, metas, compiled: Default::default() })
        }

        /// Default location relative to the repo root.
        pub fn open_default() -> Result<Self> {
            let candidates = ["artifacts", "../artifacts", "../../artifacts"];
            for c in candidates {
                let p = Path::new(c);
                if p.join("manifest.json").exists() {
                    return Self::open(p);
                }
            }
            bail!("artifacts/manifest.json not found — run `make artifacts`")
        }

        pub fn names(&self) -> Vec<String> {
            self.metas.keys().cloned().collect()
        }

        pub fn meta(&self, name: &str) -> Option<&EntryMeta> {
            self.metas.get(name)
        }

        fn entry(&self, name: &str) -> Result<std::rc::Rc<Entry>> {
            if let Some(e) = self.compiled.borrow().get(name) {
                return Ok(e.clone());
            }
            let meta = self
                .metas
                .get(name)
                .with_context(|| format!("no artifact '{name}' in manifest"))?
                .clone();
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("PJRT-compiling artifact '{name}'"))?;
            let e = std::rc::Rc::new(Entry { meta, exe });
            self.compiled.borrow_mut().insert(name.to_string(), e.clone());
            Ok(e)
        }

        /// Execute artifact `name` with host tensors, returning host tensors.
        pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let entry = self.entry(name)?;
            let meta = &entry.meta;
            if inputs.len() != meta.inputs.len() {
                bail!(
                    "artifact '{name}' wants {} inputs, got {}",
                    meta.inputs.len(),
                    inputs.len()
                );
            }
            for (i, (t, (shape, dtype))) in inputs.iter().zip(&meta.inputs).enumerate() {
                if &t.shape != shape || t.dtype != *dtype {
                    bail!(
                        "artifact '{name}' input {i}: expected {shape:?}/{dtype:?}, got {:?}/{:?}",
                        t.shape,
                        t.dtype
                    );
                }
            }
            let literals: Vec<xla::Literal> =
                inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let result = entry.exe.execute::<xla::Literal>(&literals)?[0][0]
                .to_literal_sync()?;
            // Lowered with return_tuple=True: unwrap the tuple.
            let mut parts = result.to_tuple()?;
            if parts.len() != meta.outputs.len() {
                bail!(
                    "artifact '{name}': expected {} outputs, got {}",
                    meta.outputs.len(),
                    parts.len()
                );
            }
            parts
                .drain(..)
                .zip(&meta.outputs)
                .map(|(lit, (shape, dtype))| Tensor::from_literal(&lit, *dtype, shape))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod store {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{EntryMeta, Tensor};

    /// Offline stub: keeps every `ArtifactStore` caller compiling when
    /// the `pjrt` feature (and the vendored `xla` crate) is absent.
    /// `open`/`open_default` always fail, so no instance ever exists at
    /// run time; the accessors exist purely for type-checking.
    pub struct ArtifactStore {
        _unconstructible: std::convert::Infallible,
    }

    impl ArtifactStore {
        pub fn open(_dir: &Path) -> Result<Self> {
            bail!(
                "snax was built without the `pjrt` feature — rebuild with \
                 `--features pjrt` (needs the vendored xla crate) to load artifacts"
            )
        }

        pub fn open_default() -> Result<Self> {
            Self::open(Path::new("artifacts"))
        }

        pub fn names(&self) -> Vec<String> {
            Vec::new()
        }

        pub fn meta(&self, _name: &str) -> Option<&EntryMeta> {
            None
        }

        pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!("snax was built without the `pjrt` feature")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full artifact-backed tests live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` and a `pjrt` build). Here: pure host
    // logic.

    #[test]
    fn tensor_roundtrips() {
        let t = Tensor::from_i8(&[2, 2], &[1, -2, 3, -4]);
        assert_eq!(t.as_i8(), vec![1, -2, 3, -4]);
        assert_eq!(t.elems(), 4);
        let t32 = Tensor {
            dtype: DType::I32,
            shape: vec![2],
            data: vec![1, 0, 0, 0, 254, 255, 255, 255],
        };
        assert_eq!(t32.as_i32(), vec![1, -2]);
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(DType::from_manifest("int8").unwrap(), DType::I8);
        assert_eq!(DType::from_manifest("int32").unwrap(), DType::I32);
        assert!(DType::from_manifest("float32").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_store_fails_with_guidance() {
        assert!(!PJRT_ENABLED);
        let err = ArtifactStore::open_default().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
