//! The control ISA of the SNAX cluster.
//!
//! The paper's key software-visible contract is that *all* accelerators
//! are programmed the same way: RISC-V management cores issue CSR
//! read/write instructions over a generic valid/ready register interface
//! ("uniform control"), launch jobs fire-and-forget, and synchronize
//! through hardware barriers. This module defines that contract as the
//! instruction stream executed by the simulated cores — it is the *only*
//! interface between compiler output ([`crate::compiler::codegen`]) and
//! the simulator ([`crate::sim`]), enforcing the paper's abstraction
//! structurally.


/// Identifies a control-interface endpoint (accelerator or DMA engine).
///
/// Index into [`crate::sim::Cluster`]'s unit table; assigned by
/// [`crate::config::ClusterConfig::unit_id`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct UnitId(pub u8);

/// Identifies a management core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CoreId(pub u8);

/// Identifies a hardware barrier register.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BarrierId(pub u16);

/// Layer classes used for per-layer cycle attribution (Fig. 8) and for
/// the CPU cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerClass {
    Conv,
    MaxPool,
    Dense,
    Elementwise,
    DataMove,
    Other,
}

/// A software kernel executed on a management core itself (for workload
/// sections with no matching accelerator — the paper's fallback path).
///
/// Timing comes from the RV32I cost model in [`crate::energy::calib`];
/// the functional effect (`op`) is applied to scratchpad memory when the
/// kernel retires.
#[derive(Debug, Clone)]
pub struct SwKernel {
    pub cycles: u64,
    pub class: LayerClass,
    /// Functional op applied at retire time (job-level functional /
    /// beat-level timing split, see DESIGN.md §5.2). `None` for pure
    /// busy-loops (cost-model-only benchmarks).
    pub op: Option<crate::sim::job::OpDesc>,
}

/// One instruction of a management core's compiled stream.
///
/// `CsrWrite` / `Launch` / `AwaitIdle` are the paper's loosely-coupled
/// control interface; `Barrier` is the hardware register fence; `Span*`
/// are zero-cost markers used by the report to attribute cycles to
/// layers (they model nothing and cost nothing).
#[derive(Debug, Clone)]
pub enum Instr {
    /// Write one staged (shadow) CSR of `unit`. Single cycle when the
    /// unit's shadow bank has space; stalls on valid/ready otherwise
    /// (shadow full = a launch is still pending).
    CsrWrite { unit: UnitId, reg: u16, val: u64 },
    /// Commit the staged CSR bank as a new job ("fire-and-forget"):
    /// 1 cycle, never waits for the job to finish.
    Launch { unit: UnitId },
    /// Spin until `unit` has no running or pending job. Each poll is a
    /// CSR status read costing [`POLL_INTERVAL`] cycles.
    AwaitIdle { unit: UnitId },
    /// Arrive at barrier `id` and block until all `participants` cores
    /// have arrived.
    Barrier { id: BarrierId, participants: u8 },
    /// Run a software kernel on this core (busy for `kernel.cycles`).
    Sw { kernel: SwKernel },
    /// Begin attributing this core's time to `layer`.
    SpanBegin { layer: u16, class: LayerClass },
    /// Stop attributing.
    SpanEnd { layer: u16 },
}

/// Cycles between consecutive status polls in [`Instr::AwaitIdle`]
/// (a CSR read plus branch on the RV32I core).
pub const POLL_INTERVAL: u64 = 4;

/// Barrier ids at or above this value are **system barriers**: they
/// synchronize cores across clusters of a multi-cluster
/// [`crate::sim::System`] (the cross-cluster handoff fences emitted by
/// the compiler's partition pass) instead of the cluster-local barrier
/// file. Executing one under a standalone [`crate::sim::Cluster`] is an
/// error — the program was compiled for a system.
pub const SYS_BARRIER_BASE: u16 = 0x8000;

/// A compiled multi-core program: one instruction stream per management
/// core plus the external-memory image referenced by DMA descriptors.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub streams: Vec<Vec<Instr>>,
    /// Bytes preloaded into external (AXI-side) memory before cycle 0 —
    /// network inputs and weights, as laid out by the compiler.
    pub ext_mem_init: Vec<(u64, Vec<u8>)>,
    /// Human-readable layer names, indexed by the `layer` field of
    /// span markers.
    pub layer_names: Vec<String>,
    /// Functional job descriptors referenced by `DESC` CSR writes
    /// (opaque to the modeled hardware; see [`crate::sim::job`]).
    pub descs: Vec<crate::sim::job::OpDesc>,
}

impl Program {
    pub fn n_cores(&self) -> usize {
        self.streams.len()
    }

    /// Total static instruction count (diagnostics / tests).
    pub fn n_instrs(&self) -> usize {
        self.streams.iter().map(|s| s.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// CSR register maps (per accelerator kind)
// ---------------------------------------------------------------------------

/// CSR register offsets for the GeMM accelerator (OpenGeMM-style).
///
/// The uniform CSR scheme means these are plain `u16` offsets within the
/// unit's register window; only the *addresses* differ between
/// accelerators (paper §IV-A).
pub mod gemm_csr {
    pub const M: u16 = 0; // rows / 8 (in hardware tiles)
    pub const K: u16 = 1;
    pub const N: u16 = 2;
    pub const PTR_A: u16 = 3;
    pub const PTR_B: u16 = 4;
    pub const PTR_C: u16 = 5;
    /// Streamer loop strides for A (3 nested loops).
    pub const STRIDE_A0: u16 = 6;
    pub const STRIDE_A1: u16 = 7;
    pub const STRIDE_A2: u16 = 8;
    pub const STRIDE_B0: u16 = 9;
    pub const STRIDE_B1: u16 = 10;
    pub const STRIDE_B2: u16 = 11;
    pub const STRIDE_C0: u16 = 12;
    pub const STRIDE_C1: u16 = 13;
    /// Requantization shift (0 = raw int32 output).
    pub const SHIFT: u16 = 14;
    /// Fused options bitmask (bit0 = relu).
    pub const FLAGS: u16 = 15;
    /// Within-beat row strides of the streamers (tile row pitch, bytes).
    pub const ROW_A: u16 = 16;
    pub const ROW_B: u16 = 17;
    pub const ROW_C: u16 = 18;
    /// Opaque descriptor handle (simulator-functional channel; carries
    /// the `OpDesc` index, not part of the modeled hardware cost).
    pub const DESC: u16 = 19;
    pub const N_CONFIG_REGS: u16 = 20;
}

/// CSR register offsets for the max-pool accelerator.
pub mod maxpool_csr {
    pub const H: u16 = 0;
    pub const W: u16 = 1;
    pub const C: u16 = 2;
    pub const KERNEL: u16 = 3;
    pub const STRIDE: u16 = 4;
    pub const PTR_IN: u16 = 5;
    pub const PTR_OUT: u16 = 6;
    pub const STRIDE_IN0: u16 = 7;
    pub const STRIDE_IN1: u16 = 8;
    pub const STRIDE_OUT0: u16 = 9;
    pub const DESC: u16 = 10;
    pub const N_CONFIG_REGS: u16 = 11;
}

/// CSR register offsets for the DMA engine (2-D strided transfers,
/// paper §IV-C).
pub mod dma_csr {
    pub const SRC: u16 = 0;
    pub const DST: u16 = 1;
    /// Bytes per contiguous row.
    pub const ROW_BYTES: u16 = 2;
    /// Number of rows.
    pub const ROWS: u16 = 3;
    /// Source stride between rows (bytes).
    pub const SRC_STRIDE: u16 = 4;
    /// Destination stride between rows (bytes).
    pub const DST_STRIDE: u16 = 5;
    /// Direction: 0 = ext->SPM, 1 = SPM->ext, 2 = SPM->SPM.
    pub const DIR: u16 = 6;
    pub const N_CONFIG_REGS: u16 = 7;
}

pub mod dma_dir {
    pub const EXT_TO_SPM: u64 = 0;
    pub const SPM_TO_EXT: u64 = 1;
    pub const SPM_TO_SPM: u64 = 2;
}

/// CSR register offsets for the vector-add accelerator used by the
/// `custom_accelerator` example (demonstrates third-party integration).
pub mod vecadd_csr {
    pub const LEN: u16 = 0;
    pub const PTR_A: u16 = 1;
    pub const PTR_B: u16 = 2;
    pub const PTR_OUT: u16 = 3;
    pub const DESC: u16 = 4;
    pub const N_CONFIG_REGS: u16 = 5;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_counts() {
        let p = Program {
            streams: vec![
                vec![Instr::Launch { unit: UnitId(0) }],
                vec![
                    Instr::CsrWrite { unit: UnitId(1), reg: 0, val: 1 },
                    Instr::Launch { unit: UnitId(1) },
                ],
            ],
            ..Default::default()
        };
        assert_eq!(p.n_cores(), 2);
        assert_eq!(p.n_instrs(), 3);
    }

    #[test]
    fn csr_maps_have_distinct_offsets() {
        // Register maps are dense 0..N ranges; N_CONFIG_REGS bounds them.
        assert!(gemm_csr::DESC < gemm_csr::N_CONFIG_REGS);
        assert!(maxpool_csr::DESC < maxpool_csr::N_CONFIG_REGS);
        assert!(dma_csr::DIR < dma_csr::N_CONFIG_REGS);
    }

    #[test]
    fn instr_clones_and_debug_formats() {
        let i = Instr::CsrWrite { unit: UnitId(3), reg: 7, val: 0xdead };
        let c = i.clone();
        match c {
            Instr::CsrWrite { unit, reg, val } => {
                assert_eq!(unit, UnitId(3));
                assert_eq!(reg, 7);
                assert_eq!(val, 0xdead);
            }
            _ => panic!(),
        }
        assert!(format!("{i:?}").contains("CsrWrite"));
    }
}
