//! # SNAX reproduction
//!
//! A full-stack reproduction of *"An Open-Source HW-SW Co-Development
//! Framework Enabling Efficient Multi-Accelerator Systems"* (SNAX,
//! KU Leuven MICAS, 2025) built as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * [`sim`] — a cycle-accurate micro-architectural simulator of the
//!   SNAX multi-accelerator compute cluster: multi-banked scratchpad
//!   behind a round-robin TCDM interconnect, double-buffered CSR control,
//!   nested-loop data streamers with FIFOs, a 512-bit 2-D DMA, hardware
//!   barriers, RV32I-class management cores, and the GeMM / max-pool
//!   accelerators of the paper's evaluation. This substitutes for the
//!   paper's Verilator/Questasim RTL simulation (see DESIGN.md).
//! * [`compiler`] — the SNAX-MLIR analogue: a tensor-workload IR and the
//!   paper's four automated passes (device placement, static memory
//!   allocation with double buffering, asynchronous scheduling with
//!   barrier insertion, and CSR/dataflow device programming).
//! * [`runtime`] — the PJRT bridge: loads the AOT-lowered JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`, built once by `make artifacts`)
//!   and executes them on the XLA CPU client. Python is never on the
//!   run path.
//! * [`models`] — the evaluation workload zoo (Fig. 6a network, MLPerf
//!   Tiny Deep AutoEncoder and ResNet-8, tiled matmuls) plus the
//!   bit-exact int8 datapath twin of the JAX reference.
//! * [`energy`] — area and activity-based energy models calibrated to
//!   the paper's TSMC-16 nm numbers (Fig. 7, Fig. 9, Table I).
//! * [`metrics`] — roofline analysis and report/table generation.
//! * [`baseline`] — the "conventional integration" sequential runtime
//!   used as the comparison point in Fig. 8 and Fig. 10.
//! * [`server`] — `snax serve`: a concurrent compile-and-simulate
//!   HTTP service with a content-addressed program cache, bounded
//!   worker pool, health/metrics endpoints, and graceful shutdown
//!   (DESIGN.md §6). The repo's scale-out path: many clients share one
//!   resident compiler+simulator instead of forking the CLI per run.
//!
//! ## Quickstart
//!
//! ```no_run
//! use snax::config::ClusterConfig;
//! use snax::compiler::{compile, CompileOptions};
//! use snax::models;
//!
//! let cfg = ClusterConfig::fig6d();
//! let graph = models::fig6a_graph();
//! let compiled = compile(&graph, &cfg, &CompileOptions::pipelined()).unwrap();
//! let report = snax::sim::Cluster::new(&cfg).run(&compiled.program).unwrap();
//! println!("total cycles: {}", report.total_cycles);
//! ```

pub mod baseline;
pub mod compiler;
pub mod config;
pub mod energy;
pub mod isa;
pub mod metrics;
pub mod models;
pub mod parallel;
pub mod runtime;
pub mod server;
pub mod sim;

pub use config::{ClusterConfig, SystemConfig};
