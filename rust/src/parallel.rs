//! Dependency-light scoped data parallelism (std only).
//!
//! Two consumers share this layer:
//!
//! * the functional datapath ([`crate::sim::functional`]) splits large
//!   GEMM / conv retires into output-row bands and computes them on all
//!   cores ([`for_each_chunk`]);
//! * the sweep fan-out (`snax sweep`, `POST /sweep`) runs N independent
//!   (config, program) simulations concurrently with deterministic
//!   result ordering ([`map_indexed`]).
//!
//! The design deliberately mirrors the sizing and shutdown discipline
//! of the service's [`crate::server::pool::WorkerPool`]:
//!
//! * **Sizing** — one thread per core by default
//!   ([`default_parallelism`], shared with [`ServerConfig`]'s worker
//!   count), overridable with `SNAX_THREADS`.
//! * **Shutdown** — scoped: every helper runs under
//!   [`std::thread::scope`], so workers are *always* joined before the
//!   call returns (the scoped analogue of `WorkerPool::shutdown`'s
//!   drain-then-join). No detached threads, no global state to drain.
//! * **Work stealing** — tasks self-schedule off a shared atomic
//!   cursor: a worker that finishes early immediately steals the next
//!   unclaimed chunk instead of idling behind a static partition.
//!
//! Determinism: both helpers assign task `i` to a fixed output slot
//! (band `i` of the output slice / index `i` of the result vector), so
//! results are bit-identical regardless of thread count or scheduling
//! order. Only *which worker* computes a task varies.
//!
//! [`ServerConfig`]: crate::config::ServerConfig

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Default worker count for parallel sections: `SNAX_THREADS` if set to
/// a positive integer, otherwise the host's available parallelism.
/// Cached after the first call (same sizing rule as
/// [`crate::config::ServerConfig::default`]).
pub fn default_parallelism() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Some(n) =
            std::env::var("SNAX_THREADS").ok().and_then(|v| v.parse::<usize>().ok())
        {
            if n >= 1 {
                return n;
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}

/// Covariant raw-pointer wrapper so worker threads can carve disjoint
/// `&mut` sub-slices out of one buffer. Safety rests on the chunk
/// cursor: `fetch_add` hands every chunk index to exactly one worker,
/// and chunks `[i*chunk_len, (i+1)*chunk_len)` never overlap.
struct SendPtr<T>(*mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Split `data` into contiguous chunks of `chunk_len` elements (the
/// last may be short) and run `body(ctx, chunk_index, chunk)` over all
/// of them on `ctxs.len()` scoped workers, each worker owning one
/// per-thread context (scratch buffers, etc.).
///
/// Chunks self-schedule off an atomic cursor (work stealing); with one
/// context or one chunk the loop runs inline on the caller's thread.
/// Panics in `body` propagate to the caller after all workers joined.
pub fn for_each_chunk<T, C, F>(data: &mut [T], chunk_len: usize, ctxs: &mut [C], body: F)
where
    T: Send,
    C: Send,
    F: Fn(&mut C, usize, &mut [T]) + Sync,
{
    assert!(!ctxs.is_empty(), "for_each_chunk needs at least one context");
    let chunk_len = chunk_len.max(1);
    let n_chunks = data.len().div_ceil(chunk_len);
    if ctxs.len() == 1 || n_chunks <= 1 {
        let ctx = &mut ctxs[0];
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            body(ctx, i, chunk);
        }
        return;
    }
    let len = data.len();
    let base = SendPtr(data.as_mut_ptr());
    let next = AtomicUsize::new(0);
    let workers = ctxs.len().min(n_chunks);
    std::thread::scope(|s| {
        for ctx in ctxs.iter_mut().take(workers) {
            let next = &next;
            let body = &body;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_chunks {
                    break;
                }
                let lo = i * chunk_len;
                let hi = (lo + chunk_len).min(len);
                // Safety: `i` is claimed by exactly one worker and the
                // [lo, hi) ranges of distinct chunks are disjoint.
                let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
                body(ctx, i, chunk);
            });
        }
    });
}

/// Compute `f(0..n)` on up to `threads` scoped workers and return the
/// results **in index order** — the parallel fan-out primitive for
/// sweeps. Tasks self-schedule (work stealing); ordering is
/// deterministic regardless of thread count because task `i` always
/// fills slot `i`.
pub fn map_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let base = SendPtr(slots.as_mut_ptr());
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // Safety: slot `i` is written by exactly one worker.
                unsafe { *base.0.add(i) = Some(v) };
            });
        }
    });
    slots.into_iter().map(|v| v.expect("every slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_the_slice_exactly_once() {
        for threads in [1usize, 2, 4, 7] {
            let mut data = vec![0u32; 1037];
            let mut ctxs = vec![(); threads];
            for_each_chunk(&mut data, 64, &mut ctxs, |_, i, chunk| {
                for v in chunk.iter_mut() {
                    *v += 1 + i as u32; // also check the index mapping
                }
            });
            for (pos, v) in data.iter().enumerate() {
                assert_eq!(*v, 1 + (pos / 64) as u32, "pos {pos} threads {threads}");
            }
        }
    }

    #[test]
    fn contexts_are_private_per_worker() {
        let mut data = vec![0u8; 4096];
        let mut ctxs: Vec<Vec<usize>> = vec![Vec::new(); 4];
        for_each_chunk(&mut data, 16, &mut ctxs, |seen, i, _| seen.push(i));
        let mut all: Vec<usize> = ctxs.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..256).collect::<Vec<_>>());
    }

    #[test]
    fn map_indexed_is_order_deterministic() {
        let serial = map_indexed(97, 1, |i| i * i);
        for threads in [2usize, 3, 8] {
            assert_eq!(map_indexed(97, threads, |i| i * i), serial, "{threads} threads");
        }
    }

    #[test]
    fn uneven_loads_still_complete() {
        // Front-loaded work: stealing workers must drain the tail.
        let out = map_indexed(40, 4, |i| {
            if i < 4 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            i as u64
        });
        assert_eq!(out, (0..40).collect::<Vec<u64>>());
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(map_indexed(0, 4, |i| i).is_empty());
        assert_eq!(map_indexed(1, 4, |i| i + 1), vec![1]);
        let mut data: Vec<u8> = Vec::new();
        let mut ctxs = vec![(); 2];
        for_each_chunk(&mut data, 8, &mut ctxs, |_, _, _| panic!("no chunks expected"));
    }
}
