"""L2 model graphs: shapes, conv-as-im2col equivalence, determinism."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref as R


def _rand_i8(seed, shape):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-64, 64, size=shape, dtype=np.int8))


# --- conv lowering equivalence ---------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    hw=st.integers(4, 12),
    cin=st.sampled_from([8, 16]),
    cout=st.sampled_from([8, 16]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31),
)
def test_im2col_gemm_equals_direct_conv(hw, cin, cout, stride, seed):
    """The accelerator path (im2col + GeMM) == lax.conv reference."""
    x = _rand_i8(seed, (1, hw, hw, cin))
    w = _rand_i8(seed + 1, (3, 3, cin, cout))
    got = np.asarray(R.conv2d_im2col_ref(x, w, stride=stride, pad=1))
    exp = np.asarray(R.conv2d_ref(x, w, stride=stride, pad=1))
    np.testing.assert_array_equal(got, exp)


def test_im2col_1x1_conv():
    x = _rand_i8(7, (1, 8, 8, 16))
    w = _rand_i8(8, (1, 1, 16, 32))
    got = np.asarray(R.conv2d_im2col_ref(x, w, stride=2, pad=0))
    exp = np.asarray(R.conv2d_ref(x, w, stride=2, pad=0))
    np.testing.assert_array_equal(got, exp)


def test_im2col_shape():
    x = _rand_i8(9, (2, 6, 6, 8))
    patches = R.im2col_ref(x, 3, 3, 1, 1)
    assert patches.shape == (2 * 6 * 6, 3 * 3 * 8)


# --- network-level checks ---------------------------------------------------


def test_fig6a_shape_and_dtype():
    out = M.fig6a(M.net_input("fig6a"))
    assert out.shape == (1, M.FIG6A_FC_OUT)
    assert out.dtype == jnp.int32


def test_dae_shape_and_dtype():
    out = M.dae(M.net_input("dae"))
    assert out.shape == (8, 640)
    assert out.dtype == jnp.int32


def test_resnet8_shape_and_dtype():
    out = M.resnet8(M.net_input("resnet8"))
    assert out.shape == (1, M.RESNET8_FC_OUT)
    assert out.dtype == jnp.int32


def test_networks_deterministic():
    for name in ["fig6a", "dae", "resnet8"]:
        f, _ = M.ENTRIES[name]
        a = np.asarray(f(M.net_input(name)))
        b = np.asarray(f(M.net_input(name)))
        np.testing.assert_array_equal(a, b)


def test_networks_not_degenerate():
    """Requant shifts must keep activations alive through the full depth."""
    for name in ["fig6a", "dae", "resnet8"]:
        f, _ = M.ENTRIES[name]
        out = np.asarray(f(M.net_input(name)))
        assert (out != 0).any(), f"{name} output collapsed to zero"


def test_residual_add_saturates():
    a = jnp.full((1, 4), 100, jnp.int8)
    b = jnp.full((1, 4), 100, jnp.int8)
    np.testing.assert_array_equal(np.asarray(M.residual_add(a, b)), 127)
    c = jnp.full((1, 4), -100, jnp.int8)
    np.testing.assert_array_equal(np.asarray(M.residual_add(c, c)), -128)


def test_avgpool_global():
    x = jnp.ones((1, 4, 4, 8), jnp.int8) * 7
    out = np.asarray(R.avgpool_global_ref(x))
    assert out.shape == (1, 8)
    assert (out == 7).all()


# --- shared determinism spec (LCG twin contract) ----------------------------


def test_lcg_known_vector():
    """Golden vector pinned so the Rust twin can assert the same bytes.

    If this test ever changes, rust/src/models/lcg.rs tests must change
    with it.
    """
    v = np.asarray(R.lcg_i8(42, 8))
    expected = np.array([59, 41, -23, 15, 43, 6, -19, -53], dtype=np.int8)
    np.testing.assert_array_equal(v, expected)


def test_lcg_range():
    v = np.asarray(R.lcg_i8(7, 4096))
    assert v.min() >= -64 and v.max() <= 63


def test_shift_for_k_spec():
    assert M.shift_for_k(8) == 6
    assert M.shift_for_k(128) == 8
    assert M.shift_for_k(144) == 8
    assert M.shift_for_k(640) == 9
