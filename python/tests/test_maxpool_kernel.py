"""L1 max-pool Pallas kernel vs pure-jnp oracle (bit-exact)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import maxpool as M
from compile.kernels import ref as R


def _rand_i8(seed, shape):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-128, 128, size=shape, dtype=np.int8))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.integers(4, 20),
    c=st.integers(1, 4).map(lambda v: v * 8),
    k=st.sampled_from([2, 3]),
    s=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**31),
)
def test_maxpool_matches_ref(n, hw, c, k, s, seed):
    x = _rand_i8(seed, (n, hw, hw, c))
    got = np.asarray(M.maxpool2d(x, k, s))
    exp = np.asarray(R.maxpool2d_ref(x, k, s))
    np.testing.assert_array_equal(got, exp)


def test_maxpool_all_min_values():
    """INT8_MIN padding identity must not leak."""
    x = jnp.full((1, 8, 8, 8), -128, jnp.int8)
    out = np.asarray(M.maxpool2d(x, 2, 2))
    assert (out == -128).all()


def test_maxpool_single_hot():
    x = jnp.full((1, 4, 4, 8), -128, jnp.int8)
    x = x.at[0, 1, 1, 0].set(127)
    out = np.asarray(M.maxpool2d(x, 2, 2))
    assert out[0, 0, 0, 0] == 127
    assert out[0, 1, 1, 0] == -128


def test_maxpool_rejects_bad_channel_count():
    x = jnp.zeros((1, 8, 8, 12), jnp.int8)
    with pytest.raises(ValueError, match="lanes"):
        M.maxpool2d(x, 2, 2)


def test_maxpool_output_shape_stride1():
    x = _rand_i8(1, (2, 10, 10, 16))
    out = M.maxpool2d(x, 3, 1)
    assert out.shape == (2, 8, 8, 16)


def test_maxpool_preserves_dtype():
    x = _rand_i8(2, (1, 8, 8, 8))
    assert M.maxpool2d(x, 2).dtype == jnp.int8
