"""L1 GeMM Pallas kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes (multiples of the 8-wide PE array), tile
configurations, and value edge cases; every case must be bit-exact
(integer arithmetic, no tolerance).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm as G
from compile.kernels import ref as R

dims = st.integers(1, 8).map(lambda v: v * 8)  # multiples of 8, up to 64


def _rand_i8(seed, m, n, lo=-128, hi=127):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi + 1, size=(m, n), dtype=np.int8))


@settings(max_examples=40, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31))
def test_gemm_matches_ref_random_shapes(m, k, n, seed):
    a = _rand_i8(seed, m, k)
    b = _rand_i8(seed + 1, k, n)
    np.testing.assert_array_equal(
        np.asarray(G.gemm(a, b)), np.asarray(R.gemm_ref(a, b))
    )


@settings(max_examples=20, deadline=None)
@given(
    m=dims,
    k=dims,
    n=dims,
    tm=st.sampled_from([8, 16, 32, 64]),
    tn=st.sampled_from([8, 16, 32, 64]),
    tk=st.sampled_from([8, 16, 32, 64]),
)
def test_gemm_tile_config_invariance(m, k, n, tm, tn, tk):
    """Result must not depend on the BlockSpec tiling."""
    a = _rand_i8(3, m, k)
    b = _rand_i8(4, k, n)
    np.testing.assert_array_equal(
        np.asarray(G.gemm(a, b, tm=tm, tn=tn, tk=tk)),
        np.asarray(R.gemm_ref(a, b)),
    )


def test_gemm_hw_unit_tile():
    """The accelerator's native 8x8x8 step."""
    a = R.lcg_i8(11, 64).reshape(8, 8)
    b = R.lcg_i8(12, 64).reshape(8, 8)
    np.testing.assert_array_equal(
        np.asarray(G.gemm(a, b)), np.asarray(R.gemm_ref(a, b))
    )


def test_gemm_extreme_values_no_overflow():
    """Full-range int8 extremes: int32 accumulation must not wrap.

    Worst case |acc| = K * 128 * 128 = 64 * 16384 = 2^20 << 2^31.
    """
    m = k = n = 64
    a = jnp.full((m, k), -128, jnp.int8)
    b = jnp.full((k, n), -128, jnp.int8)
    out = np.asarray(G.gemm(a, b))
    assert (out == k * 128 * 128).all()
    b2 = jnp.full((k, n), 127, jnp.int8)
    out2 = np.asarray(G.gemm(a, b2))
    assert (out2 == k * (-128) * 127).all()


def test_gemm_identity():
    n = 32
    eye = jnp.eye(n, dtype=jnp.int8)
    a = _rand_i8(5, n, n)
    np.testing.assert_array_equal(
        np.asarray(G.gemm(a, eye)), np.asarray(a, dtype=np.int32)
    )


def test_gemm_zeros():
    a = jnp.zeros((16, 24), jnp.int8)
    b = _rand_i8(6, 24, 16)
    assert (np.asarray(G.gemm(a, b)) == 0).all()


def test_gemm_rejects_non_multiple_of_8():
    a = jnp.zeros((9, 8), jnp.int8)
    b = jnp.zeros((8, 8), jnp.int8)
    with pytest.raises(ValueError, match="PE array"):
        G.gemm(a, b)


@settings(max_examples=20, deadline=None)
@given(shift=st.integers(0, 20), seed=st.integers(0, 2**31))
def test_gemm_requant_matches_ref(shift, seed):
    a = _rand_i8(seed, 16, 32)
    b = _rand_i8(seed + 9, 32, 16)
    got = np.asarray(G.gemm_requant(a, b, shift))
    exp = np.asarray(R.requantize_ref(R.gemm_ref(a, b), shift))
    np.testing.assert_array_equal(got, exp)
    assert got.dtype == np.int8


def test_requant_saturates():
    acc = jnp.array([[1 << 20, -(1 << 20), 127, -128]], jnp.int32)
    out = np.asarray(R.requantize_ref(acc, 0))
    np.testing.assert_array_equal(out, [[127, -128, 127, -128]])


def test_requant_rounds_to_nearest():
    """Round-half-up via +half then arithmetic (flooring) right shift —
    the exact hardware requantizer semantics the Rust twin must match:
    (-3+2)>>2 = -1>>2 = -1 (floor), (3+2)>>2 = 1."""
    acc = jnp.array([[3, 4, 5, -3, -4, -5, -6, -7]], jnp.int32)
    out = np.asarray(R.requantize_ref(acc, 2))
    np.testing.assert_array_equal(out, [[1, 1, 1, -1, -1, -1, -1, -2]])


def test_pick_tile_respects_divisibility():
    assert G._pick_tile(64, 32, 8) == 32
    assert G._pick_tile(40, 32, 8) == 40 // 5  # 8 divides 40, 32 doesn't
    assert G._pick_tile(8, 32, 8) == 8
    assert G._pick_tile(48, 32, 8) == 24
