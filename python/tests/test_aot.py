"""AOT lowering: every registered entry produces parseable HLO text and a
manifest consistent with its jax-side shapes."""

import json
import os

import numpy as np

from compile import aot, model


def test_lower_all_entries_produce_hlo_text():
    for name in model.ENTRIES:
        text, meta = aot.lower_entry(name)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        assert meta["return_tuple"] is True
        assert meta["sha256"]


def test_manifest_shapes_match_eval_shape():
    text, meta = aot.lower_entry("gemm_8x8x8")
    assert meta["inputs"] == [
        {"shape": [8, 8], "dtype": "int8"},
        {"shape": [8, 8], "dtype": "int8"},
    ]
    assert meta["outputs"] == [{"shape": [8, 8], "dtype": "int32"}]


def test_fig6a_manifest():
    _, meta = aot.lower_entry("fig6a")
    assert meta["inputs"][0]["shape"] == list(model.FIG6A_IN)
    assert meta["outputs"][0]["shape"] == [1, model.FIG6A_FC_OUT]
    assert meta["outputs"][0]["dtype"] == "int32"


def test_hlo_text_is_pure_hlo_no_custom_calls():
    """interpret=True must leave no Mosaic custom-calls behind — the CPU
    PJRT client in Rust cannot execute them."""
    for name in model.ENTRIES:
        text, _ = aot.lower_entry(name)
        assert "mosaic" not in text.lower(), name


def test_artifacts_dir_if_built_matches_manifest():
    """When `make artifacts` has run, files on disk match the manifest."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man = os.path.join(art, "manifest.json")
    if not os.path.exists(man):
        import pytest

        pytest.skip("artifacts not built")
    with open(man) as f:
        manifest = json.load(f)
    for name, meta in manifest.items():
        path = os.path.join(art, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        import hashlib

        with open(path) as fh:
            digest = hashlib.sha256(fh.read().encode()).hexdigest()
        assert digest == meta["sha256"], f"{name} artifact is stale"


def test_lowering_is_deterministic():
    t1, m1 = aot.lower_entry("dae")
    t2, m2 = aot.lower_entry("dae")
    assert m1["sha256"] == m2["sha256"]
    assert t1 == t2
