"""Pallas int8 GeMM kernel — the functional model of the SNAX GeMM
accelerator (OpenGeMM [25]): a 512-PE array that consumes one 8x8x8
int8 matrix-multiply per cycle with int32 accumulation.

Hardware <-> Pallas mapping (DESIGN.md §Hardware-Adaptation):

  * 8x8x8 PE array step        -> (TM, TN, TK)-tile `dot_general` with
                                  `preferred_element_type=int32`; the
                                  default tile is an integer multiple of
                                  the 8x8x8 hardware step, MXU-aligned.
  * streamer nested-loop AGU   -> `BlockSpec.index_map` over the
                                  (m, n, k) grid.
  * SPM double buffering       -> the sequential Pallas grid pipeline
                                  (k-innermost revolving accumulator).
  * accumulator registers      -> VMEM scratch `acc_ref` (int32).

VMEM footprint per grid step (documented for the DESIGN.md §Perf
estimate): TM*TK + TK*TN bytes of int8 operands + TM*TN*4 bytes of
int32 accumulator. With the default TM=TN=TK=32 that is 2 KiB + 4 KiB,
far below the ~16 MiB VMEM budget; larger tiles trade VMEM for fewer
grid steps.

`interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the artifact runs
on the Rust PJRT CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The hardware step size of the accelerator's PE array: one 8x8x8
# matmul per cycle (512 MACs).
HW_M, HW_N, HW_K = 8, 8, 8

# Default Pallas tile: a 4x4x4 super-tile of hardware steps.
DEF_TM, DEF_TN, DEF_TK = 32, 32, 32


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """Grid = (M/TM, N/TN, K/TK), K innermost. acc_ref: int32 VMEM scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...]


def _pick_tile(dim: int, pref: int, hw: int) -> int:
    """Largest tile <= pref that divides dim and is a multiple of hw."""
    if dim % hw != 0:
        raise ValueError(f"dimension {dim} not a multiple of the {hw}-wide PE array")
    t = min(pref, dim)
    while dim % t != 0 or t % hw != 0:
        t -= hw
    return t


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def gemm(
    a: jax.Array,
    b: jax.Array,
    tm: int = DEF_TM,
    tn: int = DEF_TN,
    tk: int = DEF_TK,
) -> jax.Array:
    """int8[M,K] x int8[K,N] -> int32[M,N] via the Pallas tiled kernel.

    M, N, K must be multiples of 8 (the PE-array step), matching the
    hardware constraint the SNAX compiler's tiling pass enforces.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8

    tm = _pick_tile(m, tm, HW_M)
    tn = _pick_tile(n, tn, HW_N)
    tk = _pick_tile(k, tk, HW_K)
    n_k = k // tk

    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(m // tm, n // tn, n_k),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.int32)],
        interpret=True,
    )(a, b)


def gemm_requant(
    a: jax.Array, b: jax.Array, shift: int, tm: int = DEF_TM, tn: int = DEF_TN, tk: int = DEF_TK
) -> jax.Array:
    """GeMM followed by the accelerator's output requantizer (int8 out)."""
    acc = gemm(a, b, tm, tn, tk)
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    return jnp.clip(acc, -128, 127).astype(jnp.int8)
