"""Pallas max-pool kernel — functional model of the SNAX max-pool
accelerator: 8 parallel max-pool lanes with configurable kernel size and
512-bit input/output streaming bandwidth.

Hardware <-> Pallas mapping:

  * 8 parallel channel lanes  -> channel-blocked grid (C is tiled in
                                 multiples of 8, one lane per channel).
  * streamer window walk      -> unrolled (kh, kw) strided-slice maxes
                                 inside the kernel; the BlockSpec keeps a
                                 full input row-tile resident, exactly
                                 like the accelerator's line FIFO.

VMEM per step: (k + (TH-1)*s) * W * C_TILE input bytes + TH * Wo * C_TILE
output bytes — for the paper's 2x2 pool on 32x32x16 this is ~2 KiB.

`interpret=True` so the artifact lowers to plain HLO runnable on the
CPU PJRT client from Rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 8  # hardware channel lanes


def _maxpool_kernel(x_ref, o_ref, *, k: int, s: int):
    """One (batch, channel-block) slab: pool full H x W for C_TILE lanes."""
    x = x_ref[...]  # [1, H, W, CT] int8
    _, h, w, ct = x.shape
    ho = (h - k) // s + 1
    wo = (w - k) // s + 1
    acc = None
    # The accelerator walks the k*k window with its nested-loop streamer;
    # unrolled here (k is a compile-time CSR parameter in HW too).
    for i in range(k):
        for j in range(k):
            sl = jax.lax.slice(
                x,
                (0, i, j, 0),
                (1, i + s * (ho - 1) + 1, j + s * (wo - 1) + 1, ct),
                (1, s, s, 1),
            )
            acc = sl if acc is None else jnp.maximum(acc, sl)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("k", "s"))
def maxpool2d(x: jax.Array, k: int = 2, s: int | None = None) -> jax.Array:
    """NHWC int8 max-pool. C must be a multiple of 8 (the lane count)."""
    s = s or k
    n, h, w, c = x.shape
    assert x.dtype == jnp.int8
    if c % LANES != 0:
        raise ValueError(f"C={c} not a multiple of the {LANES} pool lanes")
    ho = (h - k) // s + 1
    wo = (w - k) // s + 1
    ct = LANES
    return pl.pallas_call(
        functools.partial(_maxpool_kernel, k=k, s=s),
        grid=(n, c // ct),
        in_specs=[pl.BlockSpec((1, h, w, ct), lambda b, cc: (b, 0, 0, cc))],
        out_specs=pl.BlockSpec((1, ho, wo, ct), lambda b, cc: (b, 0, 0, cc)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), jnp.int8),
        interpret=True,
    )(x)
