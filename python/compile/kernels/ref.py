"""Pure-jnp correctness oracles for the SNAX accelerator datapaths.

These are the golden functional models the Pallas kernels (L1) and the
Rust simulator datapath (L3, `sim/accel/*`) are checked against.

All arithmetic follows the paper's 8-bit precision setting: int8 inputs,
int32 accumulation (the GeMM accelerator's 512-PE array accumulates in
wide registers), and shift-based requantization back to int8 between
layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MIN = -128
INT8_MAX = 127


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """int8[M,K] x int8[K,N] -> int32[M,N], exact accumulation."""
    assert a.dtype == jnp.int8 and b.dtype == jnp.int8, (a.dtype, b.dtype)
    return jax.lax.dot_general(
        a,
        b,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def requantize_ref(acc: jax.Array, shift: int) -> jax.Array:
    """int32 accumulator -> int8 activation via arithmetic right shift.

    Matches the simulator's requantizer: round-to-nearest (add half) then
    saturate. `shift` is a compile-time constant per layer.
    """
    assert acc.dtype == jnp.int32
    if shift > 0:
        rounded = (acc + (1 << (shift - 1))) >> shift
    else:
        rounded = acc
    return jnp.clip(rounded, INT8_MIN, INT8_MAX).astype(jnp.int8)


def relu_ref(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0)


def im2col_ref(
    x: jax.Array, kh: int, kw: int, stride: int, pad: int
) -> jax.Array:
    """NHWC int8 -> [N*Ho*Wo, kh*kw*C] patch matrix (the streamer's view).

    This mirrors how the SNAX data streamers feed the GeMM accelerator:
    nested-for-loop address generation turns a convolution into a matrix
    multiplication without an explicit data copy in hardware.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(
        x, ((0, 0), (pad, pad), (pad, pad), (0, 0)), constant_values=0
    )
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(
                jax.lax.slice(
                    xp,
                    (0, i, j, 0),
                    (n, i + stride * (ho - 1) + 1, j + stride * (wo - 1) + 1, c),
                    (1, stride, stride, 1),
                )
            )
    # [N, Ho, Wo, kh*kw, C] -> [N*Ho*Wo, kh*kw*C]
    stacked = jnp.stack(patches, axis=3)
    return stacked.reshape(n * ho * wo, kh * kw * c)


def conv2d_ref(
    x: jax.Array, w: jax.Array, stride: int = 1, pad: int = 0
) -> jax.Array:
    """NHWC int8 conv, weights HWIO int8, int32 output (no requant)."""
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )


def conv2d_im2col_ref(
    x: jax.Array, w: jax.Array, stride: int = 1, pad: int = 0
) -> jax.Array:
    """Conv as im2col + GeMM — the path the accelerator actually executes."""
    kh, kw, cin, cout = w.shape
    n, h, wi, _ = x.shape
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (wi + 2 * pad - kw) // stride + 1
    patches = im2col_ref(x, kh, kw, stride, pad)
    acc = gemm_ref(patches, w.reshape(kh * kw * cin, cout))
    return acc.reshape(n, ho, wo, cout)


def maxpool2d_ref(x: jax.Array, k: int = 2, stride: int | None = None) -> jax.Array:
    """NHWC int8 max-pooling, kernel k x k."""
    assert x.dtype == jnp.int8
    s = stride or k
    return jax.lax.reduce_window(
        x,
        jnp.int8(INT8_MIN),
        jax.lax.max,
        window_dimensions=(1, k, k, 1),
        window_strides=(1, s, s, 1),
        padding="VALID",
    )


def fc_ref(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """int8[M,K] x int8[K,N] + int32 bias -> int32[M,N]."""
    acc = gemm_ref(x, w)
    if b is not None:
        assert b.dtype == jnp.int32
        acc = acc + b[None, :]
    return acc


def avgpool_global_ref(x: jax.Array) -> jax.Array:
    """Global average pool NHWC int8 -> int8[N, C] (ResNet-8 head).

    Integer average: sum in int32, divide by count with round-to-nearest.
    """
    n, h, w, c = x.shape
    s = jnp.sum(x.astype(jnp.int32), axis=(1, 2))
    cnt = h * w
    return jnp.clip((s + cnt // 2) // cnt, INT8_MIN, INT8_MAX).astype(jnp.int8)


def lcg_np(seed: int, n: int):
    """Deterministic int8 stream shared bit-exactly with the Rust side.

    The Rust twin lives in `rust/src/models/lcg.rs`. Keep both in sync:
    state' = state * 6364136223846793005 + 1442695040888963407 (u64 wrap),
    output byte = (state' >> 33) & 0xff as i8, then halve (truncating
    toward zero) into [-63, 63] to keep deep-net accumulators tame.

    Returns numpy (not jax) so callers may cache results without leaking
    tracers when invoked under a jit trace.
    """
    import numpy as np

    out = np.empty(n, dtype=np.int64)
    state = seed & 0xFFFFFFFFFFFFFFFF
    for i in range(n):
        state = (state * 6364136223846793005 + 1442695040888963407) & 0xFFFFFFFFFFFFFFFF
        byte = (state >> 33) & 0xFF
        v = byte - 256 if byte >= 128 else byte
        out[i] = -((-v) // 2) if v < 0 else v // 2  # trunc like Rust i32 `/`
    return out.astype(np.int8)


def lcg_i8(seed: int, n: int) -> jax.Array:
    """jax-array view of `lcg_np` (see its docstring for the spec)."""
    return jnp.asarray(lcg_np(seed, n))
