"""AOT compile path: lower every registered entry point to HLO **text**
and write a manifest the Rust runtime uses to marshal literals.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the published ``xla``
crate) rejects (``proto.id() <= INT_MAX``). The text parser reassigns
ids and round-trips cleanly — see /opt/xla-example/README.md.

This runs ONCE at build time (``make artifacts``); Python is never on
the Rust request path.

Usage: python -m compile.aot --out-dir ../artifacts [--only name,...]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text.

    ``as_hlo_text(True)`` sets print_large_constants: the default printer
    elides big literals as ``constant({...})``, which the HLO text parser
    silently accepts and zero-fills — corrupting every baked weight
    tensor. The assertion guards against regressions.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(True)
    assert "constant({...})" not in text, "HLO printer elided a constant"
    return text


def lower_entry(name: str):
    fn, specs = model.ENTRIES[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_avals = jax.eval_shape(fn, *specs)
    if not isinstance(out_avals, (list, tuple)):
        out_avals = (out_avals,)
    meta = {
        "name": name,
        "inputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
        ],
        "outputs": [
            {"shape": list(o.shape), "dtype": str(o.dtype)} for o in out_avals
        ],
        # Lowered with return_tuple=True: rust must unwrap a 1-tuple (or
        # n-tuple) from the executable's single output literal.
        "return_tuple": True,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated entry names")
    args = ap.parse_args()

    names = list(model.ENTRIES) if args.only is None else args.only.split(",")
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name in names:
        text, meta = lower_entry(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = meta
        print(f"  {name}: {len(text)} chars -> {path}")

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest ({len(manifest)} entries) -> {man_path}")


if __name__ == "__main__":
    main()
