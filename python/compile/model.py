"""L2 — JAX workload graphs for the SNAX reproduction.

Every tensor op that the SNAX cluster accelerates is expressed through
the L1 Pallas kernels (`kernels.gemm`, `kernels.maxpool`); everything
else (im2col view, requantize, relu, residual add) is the lightweight
glue the RISC-V cores / streamers provide in hardware.

Three workloads, mirroring the paper's evaluation:

  * ``fig6a``   — the paper's artificial network (Fig. 6a): conv ->
                  max-pool -> fully-connected, all 8-bit.
  * ``dae``     — MLPerf Tiny v1.0 Deep AutoEncoder (ToyADMOS):
                  640 -> 128x4 -> 8 -> 128x4 -> 640 dense stack.
  * ``resnet8`` — MLPerf Tiny v1.0 ResNet-8 (CIFAR-10 class): 3 stacks
                  of residual blocks at 16/32/64 channels.

Weights are synthetic but **deterministic and shared bit-exactly with
the Rust side** via the LCG in `kernels.ref.lcg_i8` (Rust twin:
`rust/src/models/lcg.rs`); layer seeds and requant shifts are part of
the spec below (Rust twin: `rust/src/models/specs.rs`). The paper's
claims are latency/energy, not accuracy, so trained weights are not
required — but functional equivalence between the PJRT artifact and the
simulator datapath is checked bit-exactly in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import gemm as G
from .kernels import maxpool as MP
from .kernels import ref as R

# ---------------------------------------------------------------------------
# Shared spec constants (mirrored in rust/src/models/specs.rs)
# ---------------------------------------------------------------------------

NET_FIG6A = 1
NET_DAE = 2
NET_RESNET8 = 3


def layer_seed(net_id: int, layer_idx: int) -> int:
    return net_id * 1000 + layer_idx


def input_seed(net_id: int) -> int:
    return net_id * 1000


def shift_for_k(k: int) -> int:
    """Requant shift per layer: floor(log2(K))/2 + 5.

    Chosen so int8 activation scale is roughly preserved layer-to-layer
    (accumulator std grows with sqrt(K) for random int8 operands). The
    exact value is part of the spec — the Rust datapath twin
    (`rust/src/models/specs.rs`) uses the same formula, so outputs are
    bit-exact regardless.
    """
    return (k.bit_length() - 1) // 2 + 5


@functools.lru_cache(maxsize=None)
def _w_np(seed: int, *shape: int):
    n = 1
    for s in shape:
        n *= s
    return R.lcg_np(seed, n).reshape(shape)


def _w(seed: int, *shape: int) -> jax.Array:
    # The cache holds numpy only; the jax conversion happens per call so a
    # jit trace never leaks tracers into the cache.
    return jnp.asarray(_w_np(seed, *shape))


# ---------------------------------------------------------------------------
# Layer helpers (all int8 in / int8 out unless noted)
# ---------------------------------------------------------------------------


def dense(x: jax.Array, seed: int, n_out: int, relu: bool = True) -> jax.Array:
    """int8[M,K] -> int8[M,n_out] through the Pallas GeMM + requant."""
    k = x.shape[1]
    w = _w(seed, k, n_out)
    y = G.gemm_requant(x, w, shift_for_k(k))
    return jnp.maximum(y, 0) if relu else y


def dense_logits(x: jax.Array, seed: int, n_out: int) -> jax.Array:
    """Final layer: int32 logits, no requant."""
    k = x.shape[1]
    w = _w(seed, k, n_out)
    return G.gemm(x, w)


def conv(
    x: jax.Array,
    seed: int,
    cout: int,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    pad: int = 1,
    relu: bool = True,
) -> jax.Array:
    """int8 NHWC conv as im2col + Pallas GeMM (the accelerator path)."""
    n, h, wdim, cin = x.shape
    kdim = kh * kw * cin
    w = _w(seed, kdim, cout)
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (wdim + 2 * pad - kw) // stride + 1
    patches = R.im2col_ref(x, kh, kw, stride, pad)  # [N*Ho*Wo, kdim]
    y = G.gemm_requant(patches, w, shift_for_k(kdim))
    y = y.reshape(n, ho, wo, cout)
    return jnp.maximum(y, 0) if relu else y


def residual_add(a: jax.Array, b: jax.Array) -> jax.Array:
    """Saturating int8 add (ResNet skip connection)."""
    s = a.astype(jnp.int32) + b.astype(jnp.int32)
    return jnp.clip(s, -128, 127).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Fig. 6a network: conv -> max-pool -> FC, 8-bit
#
# The paper gives the layer types but not the dimensions; these are chosen
# so the baseline cycle distribution matches Fig. 8's story (convolution
# dominates ~99% of RV32I execution, max-pool >> FC among the rest), which
# is what produces the 152x / 6.9x / 3.18x cascade.
# ---------------------------------------------------------------------------

FIG6A_IN = (1, 32, 32, 16)  # NHWC int8
FIG6A_CONV_COUT = 16
FIG6A_POOL_K = 8  # 8x8 stride-8 pool -> 4x4x16 feature map
FIG6A_FC_OUT = 8


def fig6a(x: jax.Array) -> jax.Array:
    """Fig. 6a workload. x: int8[1,32,32,16] -> int32[1,8] logits."""
    y = conv(x, layer_seed(NET_FIG6A, 1), FIG6A_CONV_COUT)  # [1,32,32,16]
    y = MP.maxpool2d(y, FIG6A_POOL_K, FIG6A_POOL_K)  # [1,4,4,16]
    y = y.reshape(1, 256)
    y = jnp.tile(y, (8, 1))  # pad M to the 8-row GeMM tile
    logits = dense_logits(y, layer_seed(NET_FIG6A, 3), FIG6A_FC_OUT)
    return logits[:1]


# ---------------------------------------------------------------------------
# MLPerf Tiny Deep AutoEncoder (ToyADMOS)
# ---------------------------------------------------------------------------

DAE_IN = (8, 640)  # 8-row batch = one GeMM M-tile
DAE_DIMS = [128, 128, 128, 128, 8, 128, 128, 128, 128, 640]


def dae(x: jax.Array) -> jax.Array:
    """Deep AutoEncoder. x: int8[8,640] -> int32[8,640] reconstruction."""
    y = x
    for i, d in enumerate(DAE_DIMS[:-1]):
        y = dense(y, layer_seed(NET_DAE, i + 1), d, relu=True)
    return dense_logits(y, layer_seed(NET_DAE, len(DAE_DIMS)), DAE_DIMS[-1])


# ---------------------------------------------------------------------------
# MLPerf Tiny ResNet-8 (CIFAR-10 class), channels padded to multiples of 8
# ---------------------------------------------------------------------------

RESNET8_IN = (1, 32, 32, 8)  # CIFAR's 3 channels zero-padded to 8
RESNET8_FC_OUT = 16  # 10 classes padded to 16


def _res_stack(
    y: jax.Array, net: int, base: int, cout: int, stride: int
) -> jax.Array:
    """One ResNet-8 stack: conv-conv residual block (+1x1 shortcut when
    downsampling)."""
    z = conv(y, layer_seed(net, base), cout, stride=stride, relu=True)
    z = conv(z, layer_seed(net, base + 1), cout, relu=False)
    if stride != 1 or y.shape[3] != cout:
        sc = conv(
            y, layer_seed(net, base + 2), cout, kh=1, kw=1, stride=stride,
            pad=0, relu=False,
        )
    else:
        sc = y
    return jnp.maximum(residual_add(z, sc), 0)


def resnet8(x: jax.Array) -> jax.Array:
    """ResNet-8. x: int8[1,32,32,8] -> int32[1,16] logits (first 10 valid)."""
    y = conv(x, layer_seed(NET_RESNET8, 1), 16)  # stem, 32x32x16
    y = _res_stack(y, NET_RESNET8, 2, 16, 1)  # 32x32x16
    y = _res_stack(y, NET_RESNET8, 5, 32, 2)  # 16x16x32
    y = _res_stack(y, NET_RESNET8, 8, 64, 2)  # 8x8x64
    y = R.avgpool_global_ref(y)  # [1, 64]
    y = jnp.tile(y, (8, 1))  # pad M to the 8-row GeMM tile
    logits = dense_logits(y, layer_seed(NET_RESNET8, 11), RESNET8_FC_OUT)
    return logits[:1]


# ---------------------------------------------------------------------------
# Entry-point registry consumed by aot.py and by tests
# ---------------------------------------------------------------------------


def gemm_entry(m: int, k: int, n: int):
    """Standalone GeMM artifact (used by the runtime for arbitrary tiles)."""

    def f(a, b):
        return G.gemm(a, b)

    specs = (
        jax.ShapeDtypeStruct((m, k), jnp.int8),
        jax.ShapeDtypeStruct((k, n), jnp.int8),
    )
    return f, specs


def maxpool_entry(n: int, h: int, w: int, c: int, k: int, s: int):
    def f(x):
        return MP.maxpool2d(x, k, s)

    return f, (jax.ShapeDtypeStruct((n, h, w, c), jnp.int8),)


ENTRIES = {
    "fig6a": (fig6a, (jax.ShapeDtypeStruct(FIG6A_IN, jnp.int8),)),
    "dae": (dae, (jax.ShapeDtypeStruct(DAE_IN, jnp.int8),)),
    "resnet8": (resnet8, (jax.ShapeDtypeStruct(RESNET8_IN, jnp.int8),)),
    "gemm_64x64x64": gemm_entry(64, 64, 64),
    "gemm_8x8x8": gemm_entry(8, 8, 8),
    "maxpool_32x32x16_k2": maxpool_entry(1, 32, 32, 16, 2, 2),
}


def net_input(name: str) -> jax.Array:
    """The deterministic input tensor for a registered network."""
    net_id = {"fig6a": NET_FIG6A, "dae": NET_DAE, "resnet8": NET_RESNET8}[name]
    shape = {"fig6a": FIG6A_IN, "dae": DAE_IN, "resnet8": RESNET8_IN}[name]
    n = 1
    for s in shape:
        n *= s
    return R.lcg_i8(input_seed(net_id), n).reshape(shape)
