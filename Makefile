# SNAX reproduction — build/test entry points.
#
# The Rust workspace root is this directory (members: rust/). The
# `artifacts` target needs the Python toolchain (JAX/Pallas) and is
# only required for `--features pjrt` builds.

.PHONY: build test fmt fmt-check clippy memo-equivalence system-equivalence system-parallel-equivalence serve serve-smoke chaos-smoke crash-smoke fleet-smoke loadgen-smoke profile-smoke bench bench-func bench-all bench-smoke artifacts

build:
	cargo build --release

test:
	cargo test -q

# Format in place; `fmt-check` is the non-mutating CI gate.
fmt:
	cargo fmt

fmt-check:
	cargo fmt --check

# Lint gate (mirrors the CI clippy job).
clippy:
	cargo clippy --all-targets -- -D warnings

# Phase-memoization equivalence: memo-on vs memo-off vs exact, plus
# shared-phase-cache replay determinism (mirrors the CI memo step).
memo-equivalence:
	cargo test -q --test engine_equivalence
	cargo test -q memo_

# Multi-cluster system equivalence: system-of-1 byte identity against
# the standalone cluster engine on the fig6/fig8/table1 matrix, plus
# the multi-cluster SoC end-to-end suite (partition pass, shared-NoC
# contention, handoff fidelity). Mirrors the CI system step.
system-equivalence:
	cargo test -q --test engine_equivalence system_of_one
	cargo test -q --test system_soc

# Conservative-PDES driver equivalence (DESIGN.md §14): SystemReports
# must be byte-identical at any thread count — both engines, memo on or
# off, ledgered or not — and memo-under-contention replays must match
# memo-off bit for bit. Mirrors the CI system-parallel step.
system-parallel-equivalence:
	cargo test -q --test system_soc byte_identical_at_any_thread_count
	cargo test -q --test system_soc memo_under_contention
	cargo test -q --lib sim::system::tests

# Run the compile-and-simulate service (ctrl-c / SIGTERM for graceful
# shutdown).
serve: build
	./target/release/snax serve

# Build and run the loopback integration test: ephemeral-port server,
# concurrent POST /simulate, byte-identical-report + cache-hit checks.
serve-smoke:
	cargo test -q --test integration_server

# Chaos harness (DESIGN.md §11): drive the server under deterministic
# fault injection (panic/slow/stall) and assert the fault-tolerance
# contract — deadlines cut runs off with 504 + partial progress,
# DELETE /jobs/:id cancels cooperatively, identical concurrent requests
# coalesce onto one execution, the breaker opens and recovers, and no
# worker slot is ever lost.
chaos-smoke:
	cargo test -q --test chaos

# Crash-recovery smoke (DESIGN.md §12): run the real binary, kill it
# mid-job with the deterministic `crash:p` fault, and assert the job
# journal replays on restart — the orphaned job auto-resumes to a
# report byte-identical to an uninterrupted run, and finished jobs stay
# pollable without re-execution.
crash-smoke:
	cargo test -q --test crash_recovery

# Fleet smoke (DESIGN.md §13): spawn real binaries as a consistent-hash
# fleet and assert the sharing contract — remote cache hits are
# byte-identical, a SIGKILL'd peer causes zero non-2xx on the
# survivors, a restarted peer is probed back into service, and an
# injected partition degrades to local-only with single-node bytes.
fleet-smoke:
	cargo test -q --test fleet

# Closed-loop load generator against a loopback server: retrying
# clients honoring Retry-After; rewrites BENCH_serve_loadgen.json and
# (with the floor flag) enforces rust/benches/serve_loadgen_floor.json.
# The --peers leg runs the same closed loop against a two-node fleet,
# rewriting BENCH_serve_fleet.json (remote-hit rate, shed rate) floored
# by rust/benches/serve_fleet_floor.json.
loadgen-smoke:
	SNAX_BENCH_ENFORCE_FLOOR=1 cargo run --release --example serve_loadgen
	SNAX_BENCH_ENFORCE_FLOOR=1 cargo run --release --example serve_loadgen -- --peers

# Cycle-accounting profiler smoke (mirrors the CI profile step): run
# `snax profile` on the single-cluster and multi-cluster shapes and
# validate the JSON envelope schema + conservation invariant from the
# outside (stdlib-only checker).
profile-smoke: build
	./target/release/snax profile --net fig6a --cluster fig6d --json /tmp/snax-profile-fig6a.json
	python3 scripts/check_profile_json.py /tmp/snax-profile-fig6a.json
	./target/release/snax profile --net resnet8 --system soc4 --pipelined --json /tmp/snax-profile-soc4.json
	python3 scripts/check_profile_json.py /tmp/snax-profile-soc4.json --system

# Simulator-throughput bench: runs both engines on every leg and
# rewrites BENCH_sim_speed.json (the cross-PR perf trajectory record).
bench:
	cargo bench --bench sim_speed

# Functional-datapath bench: blocked int8 GEMM/conv microkernel vs the
# naive oracle; rewrites BENCH_func_speed.json.
bench-func:
	cargo bench --bench func_speed

# Fast CI variant: few reps, fail below the checked-in floors
# (rust/benches/{sim_speed,func_speed,soc_scale}_floor.json).
bench-smoke:
	SNAX_BENCH_REPS=2 SNAX_BENCH_ENFORCE_FLOOR=1 cargo bench --bench sim_speed
	SNAX_BENCH_REPS=5 SNAX_BENCH_ENFORCE_FLOOR=1 cargo bench --bench func_speed
	SNAX_BENCH_REPS=3 SNAX_BENCH_ENFORCE_FLOOR=1 cargo bench --bench soc_scale

# Every figure/table reproduction bench.
bench-all:
	cargo bench

# AOT-lower the JAX/Pallas entry points to artifacts/ (build-time only;
# see python/compile/aot.py). Needed for `--features pjrt`.
artifacts:
	python3 python/compile/aot.py
