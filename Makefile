# SNAX reproduction — build/test entry points.
#
# The Rust workspace root is this directory (members: rust/). The
# `artifacts` target needs the Python toolchain (JAX/Pallas) and is
# only required for `--features pjrt` builds.

.PHONY: build test fmt serve serve-smoke bench artifacts

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --check

# Run the compile-and-simulate service (ctrl-c / SIGTERM for graceful
# shutdown).
serve: build
	./target/release/snax serve

# Build and run the loopback integration test: ephemeral-port server,
# concurrent POST /simulate, byte-identical-report + cache-hit checks.
serve-smoke:
	cargo test -q --test integration_server

bench:
	cargo bench

# AOT-lower the JAX/Pallas entry points to artifacts/ (build-time only;
# see python/compile/aot.py). Needed for `--features pjrt`.
artifacts:
	python3 python/compile/aot.py
