#!/usr/bin/env python3
"""Validate a `snax profile --json` envelope (stdlib only).

Usage: check_profile_json.py out.json [--system]

Checks the schema the CLI promises (DESIGN.md §10) and re-verifies the
conservation invariant from the outside: per ledger row, the category
cycle counts must sum to the ledger's total_cycles.
"""

import json
import sys

CATS = [
    "compute",
    "dma-wait",
    "bank-conflict",
    "barrier-wait",
    "sys-barrier-wait",
    "noc-denied",
    "launch-stall",
    "poll",
    "idle",
]


def fail(msg):
    print(f"profile-json check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def check_ledger(lg, where):
    if not isinstance(lg, dict):
        fail(f"{where}: ledger is not an object")
    total = lg.get("total_cycles")
    if not isinstance(total, int) or total <= 0:
        fail(f"{where}: bad ledger total_cycles {total!r}")
    rows = lg.get("rows")
    if not isinstance(rows, list) or not rows:
        fail(f"{where}: ledger rows missing or empty")
    for row in rows:
        name = row.get("name")
        cats = row.get("cats")
        if not isinstance(name, str) or not name:
            fail(f"{where}: row without a name: {row!r}")
        if not isinstance(cats, dict) or sorted(cats) != sorted(CATS):
            fail(f"{where}/{name}: cats keys != category taxonomy: {sorted(cats or {})}")
        if any(not isinstance(v, int) or v < 0 for v in cats.values()):
            fail(f"{where}/{name}: non-natural category cycle count: {cats}")
        if sum(cats.values()) != total:
            fail(
                f"{where}/{name}: conservation violated: "
                f"sum {sum(cats.values())} != total {total}"
            )
        if "bottleneck" not in row:
            fail(f"{where}/{name}: missing bottleneck field")
    return total


def check_cluster(c, where):
    lg_total = check_ledger(c.get("ledger"), f"{where}/ledger")
    if c.get("total_cycles") != lg_total:
        fail(f"{where}: cluster total {c.get('total_cycles')} != ledger total {lg_total}")
    layers = c.get("layers")
    if not isinstance(layers, list) or not layers:
        fail(f"{where}: layers missing or empty")
    for l in layers:
        for key in ("id", "name", "busy_cycles", "span_cycles", "span_share"):
            if key not in l:
                fail(f"{where}: layer missing {key}: {l!r}")
    rf = c.get("roofline")
    if not isinstance(rf, dict):
        fail(f"{where}: roofline missing")
    for key in (
        "intensity_ops_per_byte",
        "achieved_ops_per_cycle",
        "bound_ops_per_cycle",
        "peak_ops_per_cycle",
        "utilization",
    ):
        if not isinstance(rf.get(key), (int, float)):
            fail(f"{where}: roofline missing numeric {key}")
    if rf["achieved_ops_per_cycle"] > rf["bound_ops_per_cycle"] * 1.0001:
        fail(f"{where}: achieved exceeds the roofline bound: {rf}")


def main():
    if len(sys.argv) < 2:
        fail("usage: check_profile_json.py out.json [--system]")
    path, system = sys.argv[1], "--system" in sys.argv[2:]
    with open(path) as f:
        doc = json.load(f)
    for key in ("net", "mode", "total_cycles", "clusters"):
        if key not in doc:
            fail(f"envelope missing {key}")
    clusters = doc["clusters"]
    if not isinstance(clusters, list) or not clusters:
        fail("clusters missing or empty")
    for i, c in enumerate(clusters):
        check_cluster(c, f"clusters[{i}]")
    if system:
        if "system" not in doc or "partition" not in doc:
            fail("system envelope missing system/partition")
        noc = doc.get("noc_ledger")
        check_ledger(noc, "noc_ledger")
        if not any(r.get("name") == "noc" for r in noc["rows"]):
            fail("noc_ledger has no 'noc' row")
    print(f"profile-json check ok: {path} ({len(clusters)} cluster(s))")


if __name__ == "__main__":
    main()
